"""Runtime memory-pool subsystem: capacity accounting, eviction order,
transfer-engine overlap semantics, backend fallback, and executed-residency
agreement with the compiler's memory simulator."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub, small_graph
from repro.core import memsim
from repro.core.costmodel import TPU_V5E
from repro.core.jax_exec import run_baseline
from repro.core.planner import HyperOffloadPlanner
from repro.pool import (
    MemoryPoolManager, OffloadPlanExecutor, PoolCapacityError, TierSpec,
    TierState, TierTopology, TransferEngine, default_pool, sweep_topologies,
)
from repro.pool import backend as B

given, settings, st = hypothesis_or_stub()


def _arr(kb: int, fill: float = 1.0) -> jax.Array:
    return jnp.full((kb * 256,), fill, jnp.float32)   # kb KiB


# ---------------------------------------------------------------------------
# backend probing + fallback
# ---------------------------------------------------------------------------


def test_backend_probe_and_host_roundtrip():
    caps = B.capabilities()
    # the probed host kind must actually be addressable (or None → NumPy)
    if caps.host_kind is not None:
        assert caps.host_kind in caps.memory_kinds
    be = B.make_host_backend()
    x = jnp.arange(512.0)
    h = be.put(x)
    assert be.holds(h)
    np.testing.assert_array_equal(np.asarray(be.get(h)), np.asarray(x))


def test_numpy_backend_is_always_available():
    be = B.NumpyHostBackend()
    x = jnp.arange(64.0).reshape(8, 8)
    h = be.put(x)
    assert isinstance(h, np.ndarray) and be.holds(h)
    y = be.get(h)
    assert isinstance(y, jax.Array)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_to_host_to_device_helpers():
    x = jnp.ones((4, 4), jnp.bfloat16)
    parked = B.to_host(x)
    assert B.is_host_resident(parked)
    back = B.to_device(parked)
    np.testing.assert_array_equal(np.asarray(back, np.float32),
                                  np.asarray(x, np.float32))


# ---------------------------------------------------------------------------
# manager: capacity accounting + eviction
# ---------------------------------------------------------------------------


def test_capacity_accounting_and_drop():
    p = default_pool(host_capacity=1 << 20)
    p.put("a", _arr(64))
    p.put("b", _arr(128))
    used, cap = p.occupancy("host")
    assert used == (64 + 128) * 1024 and cap == 1 << 20
    assert p.snapshot()["bytes_stored"] == used
    p.drop("a")
    assert p.occupancy("host")[0] == 128 * 1024
    assert "a" not in p and "b" in p
    with pytest.raises(KeyError):
        p.get("a")


def test_eviction_spills_lru_lowest_priority_first():
    # host holds exactly 2 × 256 KiB pages; third put must spill one
    p = default_pool(host_capacity=2 * 256 * 1024)
    p.put("old", _arr(256, 1.0))
    p.put("new", _arr(256, 2.0))
    p.get("old")                       # "old" is now more recently used
    p.put("third", _arr(256, 3.0))
    # LRU victim is "new"; it spilled down to the remote tier, not vanished
    assert p.tier_of("new") == "remote" and p.tier_of("old") == "host"
    assert p.tier_of("third") == "host"
    np.testing.assert_array_equal(np.asarray(p.get("new")),
                                  np.asarray(_arr(256, 2.0)))
    assert p.snapshot()["evictions"] == 1

    # planner-priority hints beat recency: low-priority entries go first
    p2 = default_pool(host_capacity=2 * 256 * 1024)
    p2.put("cheap", _arr(256), priority=0.0)
    p2.put("precious", _arr(256), priority=10.0)
    p2.get("cheap")                    # recency would protect "cheap"...
    p2.put("x", _arr(256))
    assert p2.tier_of("cheap") == "remote"      # ...but priority wins
    assert p2.tier_of("precious") == "host"


def test_set_priority_reranks_eviction():
    """`set_priority` re-ranks an existing entry for eviction in place —
    no data movement, no recency bump — and ignores unknown keys."""
    p = default_pool(host_capacity=2 * 256 * 1024)
    p.put("a", _arr(256, 1.0), priority=5.0)
    p.put("b", _arr(256, 2.0), priority=0.0)
    # demote "a" below "b": priority alone must now pick "a" as victim
    p.set_priority("a", -1.0)
    p.set_priority("ghost", 9.0)                 # unknown key: silent no-op
    assert "ghost" not in p
    p.put("c", _arr(256, 3.0))
    assert p.tier_of("a") == "remote"            # demoted entry spilled...
    assert p.tier_of("b") == "host"              # ...not the LRU-older "b"
    # re-ranking never touched the payload
    np.testing.assert_array_equal(np.asarray(p.get("a")),
                                  np.asarray(_arr(256, 1.0)))
    # promote back above "b": next pressure evicts "b" instead
    p.set_priority("a", 10.0)
    p.set_priority("b", -5.0)
    p.put("d", _arr(256, 4.0))
    assert p.tier_of("b") == "remote"
    assert p.tier_of("c") == "host" or p.tier_of("d") == "host"


def test_pinned_entries_never_evict_and_last_tier_overflows():
    host = TierState("host", B.make_host_backend(), capacity=256 * 1024)
    p = MemoryPoolManager([host])      # single tier: nowhere to spill
    p.put("pinned", _arr(256), tier="host", pinned=True)
    with pytest.raises(PoolCapacityError):
        p.put("overflow", _arr(256), tier="host")
    assert p.tier_of("pinned") == "host"


def test_reserve_release_and_headroom():
    """Admission-control ledger: reservations count against the named
    tiers' combined capacity; release returns the headroom."""
    p = default_pool(device_capacity=1 << 20, host_capacity=1 << 20)
    tiers = ("device", "host")
    assert p.headroom(tiers) == 2 << 20
    assert p.reserve("r1", 1 << 20, tiers)
    assert p.reserve("r2", 512 << 10, tiers)
    assert not p.reserve("r3", 1 << 20, tiers)      # would over-commit
    assert p.reserved_bytes(tiers) == (1 << 20) + (512 << 10)
    p.put("a", _arr(256), tier="host")              # occupancy counts too
    assert p.headroom(tiers) == (512 << 10) - 256 * 1024
    p.release("r1")
    assert p.reserve("r3", 1 << 20, tiers)
    p.release("r2")
    p.release("r3")
    p.release("r3")                                  # no-op re-release
    assert p.reserved_bytes() == 0
    assert p.snapshot()["reserved"] == 0
    # an unbounded tier in the set always admits
    assert p.reserve("big", 1 << 40, ("device", "host", "remote"))


def test_evict_listener_fires_on_spill():
    p = default_pool(host_capacity=256 * 1024)
    seen = []
    p.add_evict_listener(lambda entry, dst: seen.append((entry.key, dst)))
    p.put("cold", _arr(256))
    p.put("hot", _arr(256))                          # spills "cold" → remote
    assert seen == [("cold", "remote")]
    assert p.tier_of("cold") == "remote"


def test_shared_pool_across_caches_does_not_collide():
    """The documented shared-pool-across-layers setup: page keys are
    namespaced per cache instance."""
    from repro.offload.kvcache import PagedKVCache

    pool = default_pool()
    b, hkv, d, page = 1, 1, 8, 4
    c1 = PagedKVCache.create(batch=b, max_seq=8, page_size=page,
                             n_kv_heads=hkv, head_dim=d, pool=pool)
    c2 = PagedKVCache.create(batch=b, max_seq=8, page_size=page,
                             n_kv_heads=hkv, head_dim=d, pool=pool)
    ones = jnp.ones((b, page, hkv, d))
    c1.prefill(ones, ones)
    c2.prefill(ones * 7.0, ones * 7.0)
    k1, _ = c1.fetch_pages([0])
    k2, _ = c2.fetch_pages([0])
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(ones)[None])
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(ones * 7.0)[None])


# ---------------------------------------------------------------------------
# declarative tier topology
# ---------------------------------------------------------------------------


def test_topology_default_reproduces_three_tier_pool():
    """`TierTopology.default()` is the historical pool, exactly: names,
    admission set, store tier, and backend classes per slot."""
    p = default_pool()
    assert p.spill_order == ["device", "host", "remote"]
    assert p.top_tier == "device"
    assert p.default_store_tier == "host"
    assert p.admission_tiers == ("device", "host")
    assert isinstance(p.tiers["device"].backend, B.DeviceBackend)
    assert isinstance(p.tiers["remote"].backend, B.ModeledTierBackend)
    assert not p.tiers["remote"].backend.throttled
    # legacy capacity kwargs land on the matching TierSpec slots
    p2 = default_pool(device_capacity=1 << 20, host_capacity=1 << 21,
                      remote_capacity=1 << 22)
    assert [p2.tiers[n].capacity for n in p2.spill_order] == [
        1 << 20, 1 << 21, 1 << 22]
    with pytest.raises(ValueError, match="capacities"):
        default_pool(host_capacity=1 << 20,
                     topology=TierTopology.default())


def test_topology_validation_and_roundtrip():
    with pytest.raises(ValueError, match="kind"):
        TierSpec("x", kind="tape")
    with pytest.raises(ValueError, match="only"):
        TierSpec("x", kind="host", read_bw=1e9)      # throttle on real tier
    with pytest.raises(ValueError, match="first"):
        TierTopology(tiers=(TierSpec("h", kind="host"),
                            TierSpec("d", kind="device")))
    with pytest.raises(ValueError, match="duplicate"):
        TierTopology(tiers=(TierSpec("a"), TierSpec("a")))
    with pytest.raises(ValueError, match="admit"):
        TierTopology(tiers=(TierSpec("a", admit=False),))
    topo = TierTopology.default(host_capacity=1 << 20)
    assert TierTopology.from_dict(topo.to_dict()) == topo
    with pytest.raises(ValueError, match="unknown"):
        TierTopology.from_dict({"tiers": [{"name": "a", "kindd": "host"}]})
    # sweeps rebuild only the named modeled tier
    sw = sweep_topologies(topo, "remote", read_bws=[1e9, 2e9])
    assert [s.spec("remote").read_bw for s in sw] == [1e9, 2e9]
    assert all(s.spec("host") == topo.spec("host") for s in sw)
    with pytest.raises(ValueError, match="modeled"):
        sweep_topologies(topo, "host", read_bws=[1e9])


def test_modeled_tier_enforces_bandwidth():
    """A modeled tier's sleep-throttle holds measured per-transfer read
    bandwidth within 20% of its spec (ISSUE acceptance: the paper's
    Fig. 6 D2H sweep needs trustworthy grid points). MiB-scale arrays
    keep the per-transfer latency term negligible."""
    bw = 200e6                                       # 200 MB/s
    topo = TierTopology(tiers=(
        TierSpec("device", kind="device"),
        TierSpec("pooled", kind="modeled", read_bw=bw, write_bw=bw),
    ))
    p = default_pool(topology=topo)
    x = jnp.ones((1 << 20,), jnp.float32)            # 4 MiB
    for i in range(3):
        p.put(f"k{i}", x, tier="pooled")
        p.get(f"k{i}")
    pairs = p.snapshot()["transfer"]["pairs"]
    for pair in ("pooled->device", "device->pooled"):
        meas = pairs[pair]
        assert meas["transfers"] == 3
        measured_bw = meas["bytes"] / meas["busy_s"]
        assert measured_bw == pytest.approx(bw, rel=0.20), pair
    p.close()


def test_n_tier_chain_spills_step_by_step():
    """A deeper-than-three chain spills strictly one hop at a time and
    get() works from any depth."""
    unit = 64 * 1024
    topo = TierTopology(tiers=(
        TierSpec("l0", kind="numpy", capacity=unit),
        TierSpec("l1", kind="numpy", capacity=unit),
        TierSpec("l2", kind="numpy", capacity=unit),
        TierSpec("l3", kind="numpy"),
    ))
    p = default_pool(topology=topo)
    hops = []
    p.add_evict_listener(lambda e, dst: hops.append((e.key, dst)))
    for i in range(4):
        p.put(f"k{i}", _arr(64, float(i)), tier="l0")
    # k0 rippled down the whole chain, one hop per incoming page
    assert [p.tier_of(f"k{i}") for i in range(4)] == ["l3", "l2", "l1", "l0"]
    names = list(topo.names)
    for key, dst in hops:
        assert names.index(dst) >= 1                 # only ever downward
    np.testing.assert_array_equal(np.asarray(p.get("k0")),
                                  np.asarray(_arr(64, 0.0)))
    p.close()


@given(st.integers(2, 5), st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_property_spill_conserves_bytes_and_chain_order(n_tiers, seed):
    """For arbitrary N-tier topologies and workloads: spill-down moves
    entries strictly one hop down the chain, bounded tiers never exceed
    capacity, and bytes are conserved across the hierarchy."""
    rng = np.random.default_rng(seed)
    unit = 16 * 1024
    # bounded tiers hold >= the largest page, so a spill chain always
    # terminates at the unbounded bottom tier
    tiers = tuple(
        TierSpec(f"t{i}", kind="numpy",
                 capacity=int(rng.integers(2, 5)) * unit)
        for i in range(n_tiers - 1)
    ) + (TierSpec(f"t{n_tiers - 1}", kind="numpy"),)
    topo = TierTopology(tiers=tiers)
    p = default_pool(topology=topo)
    names = list(topo.names)
    tier_at = {}                                     # key -> expected index
    hops = []

    def on_evict(entry, dst):
        # checked live: one hop down from where the entry last was
        hops.append((entry.key, dst))
        assert names.index(dst) == tier_at[entry.key] + 1, (entry.key, dst)
        tier_at[entry.key] = names.index(dst)

    p.add_evict_listener(on_evict)
    live = {}
    for i in range(int(rng.integers(4, 12))):
        key = f"k{i % 6}"                            # re-puts included
        kb = int(rng.integers(1, 3)) * 16            # 16 or 32 KiB
        p.put(key, _arr(kb, float(i)), tier=names[0],
              priority=float(rng.integers(0, 3)))
        live[key] = (kb, float(i))
        tier_at[key] = 0
        if rng.integers(0, 2) and live:
            probe = str(rng.choice(sorted(live)))
            p.get(probe)                             # recency traffic
            tier_at[probe] = names.index(p.tier_of(probe))
        if rng.integers(0, 2) and live:
            p.set_priority(str(rng.choice(sorted(live))),
                           float(rng.integers(-2, 5)))
    # bounded tiers respect capacity; bytes are conserved
    for spec in topo:
        if spec.capacity is not None:
            used, cap = p.occupancy(spec.name)
            assert used <= cap, spec.name
    total = sum(p.occupancy(n)[0] for n in names)
    assert total == sum(kb * 1024 for kb, _ in live.values())
    assert sum(p.snapshot()[f"tier/{n}"]["entries"]
               for n in names) == len(live)
    # the chain actually exercised spilling for multi-page workloads
    assert all(names.index(p.tier_of(k)) == tier_at[k] for k in live)
    # payload integrity from wherever each entry landed
    for key, (kb, fill) in live.items():
        np.testing.assert_array_equal(np.asarray(p.get(key)),
                                      np.asarray(_arr(kb, fill)))
    p.close()


# ---------------------------------------------------------------------------
# KV page codecs: round-trip bounds + on-wire byte accounting
# ---------------------------------------------------------------------------


def _rand_page(seed=0, shape=(2, 8, 2, 16)):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize("name", ["int8", "fp8"])
def test_codec_roundtrip_within_hard_bound(name):
    from repro.pool import make_codec, roundtrip_bound
    c = make_codec(name)
    x = _rand_page(1)
    payload, scale = c.encode(x)
    y = c.decode(payload, scale, str(x.dtype))
    assert y.shape == x.shape and y.dtype == x.dtype
    err = float(jnp.max(jnp.abs(y - x)))
    bound = roundtrip_bound(c, float(jnp.max(jnp.abs(x))))
    assert err <= bound, (name, err, bound)
    # 4-byte payloads become 1-byte payloads (+4B scale)
    assert c.encoded_nbytes(x.shape, x.dtype) == x.size + 4
    assert c.ratio(4) == 0.25


def test_codec_none_and_unknown():
    from repro.pool import make_codec
    assert make_codec(None) is None and make_codec("none") is None
    with pytest.raises(ValueError, match="unknown"):
        make_codec("zstd")


def test_codec_pool_records_wire_bytes_not_decoded():
    """The byte-accounting bugfix: tier occupancy, bytes_stored/fetched,
    and the per tier-pair calibration table must all see *encoded* bytes —
    decoded nbytes would inflate measured bandwidth 4× under int8."""
    p = default_pool(topology=TierTopology.default(),
                     codec="int8", codec_below="host")
    x = _rand_page(2)                                 # 4 KiB decoded
    wire = x.size + 4
    e = p.put("pg", x, tier="host")
    assert e.nbytes == wire
    snap = p.snapshot()
    assert snap["tier/host"]["used"] == wire
    assert snap["bytes_stored"] == wire
    assert snap["transfer"]["pairs"]["device->host"]["bytes"] == wire
    y = p.get("pg")
    assert y.dtype == x.dtype and y.shape == x.shape
    assert float(jnp.max(jnp.abs(y - x))) < 0.05
    snap = p.snapshot()
    assert snap["bytes_fetched"] == wire
    assert snap["transfer"]["pairs"]["host->device"]["bytes"] == wire
    # the measured-bandwidth path consumes these pairs directly
    from repro.core.calibration import measurements_from_pairs
    ms = measurements_from_pairs(snap["transfer"]["pairs"])
    assert ms[("host", "device")].nbytes == wire
    p.close()


def test_codec_spill_encodes_at_boundary_and_moves_payload_below():
    """Device→host spill quantizes (wire bytes shrink 4×); host→remote
    moves the payload as-is — no re-encode, so quantization error does
    NOT compound across the lower hop."""
    x = _rand_page(3, (16, 16))                       # 1024 B decoded
    wire = 16 * 16 + 4
    p = default_pool(
        topology=TierTopology.default(device_capacity=1500,
                                      host_capacity=300),
        codec="int8", codec_below="host")
    p.put("p0", x, tier="device")
    assert p.entries["p0"].nbytes == x.nbytes         # device: decoded
    p.put("p1", x, tier="device")                     # spills p0 → host
    assert p.tier_of("p0") == "host"
    assert p.entries["p0"].nbytes == wire
    one_hop = np.asarray(p.get("p0"))                 # single quantization
    p.put("p2", x, tier="device")                     # p1→host, p0→remote
    assert p.tier_of("p0") == "remote"
    assert p.entries["p0"].nbytes == wire
    pairs = p.snapshot()["transfer"]["pairs"]
    assert pairs["host->remote"]["bytes"] == wire     # on-wire, encoded
    # byte-identical to the one-hop decode: the payload moved untouched
    np.testing.assert_array_equal(np.asarray(p.get("p0")), one_hop)
    p.close()


def test_codec_raises_admission_capacity():
    """The admission bugfix: reservations stay in decoded bytes, but a
    codec tier counts at decoded-equivalent capacity — 4× the raw byte
    budget for fp32 pages in int8 — so quantization admits more, not
    fewer, requests."""
    p = default_pool(
        topology=TierTopology.default(device_capacity=0, host_capacity=1100,
                                      remote_capacity=0),
        codec="int8", codec_below="host")
    # raw-byte ledger (itemsize=None): 4000 decoded B can't fit in 1100
    assert not p.reserve("raw", 4000, ("host",))
    # decoded-equivalent ledger: 1100 B of int8 holds ~4384 fp32 bytes
    assert p.reserve("scaled", 4000, ("host",), itemsize=4)
    assert p.headroom(("host",), itemsize=4) == 4 * 1100 - 4000
    p.release("scaled")
    # occupancy is scaled per tier too: a parked page charges wire bytes
    x = _rand_page(4, (16, 16))                       # 1024 B decoded
    p.put("pg", x, tier="host")                       # 260 B at rest
    assert p.headroom(("host",), itemsize=4) == 4 * (1100 - 260)
    p.close()


def test_codec_boundary_validation():
    with pytest.raises(ValueError, match="accelerator"):
        default_pool(codec="int8", codec_below="device")
    with pytest.raises(ValueError, match="not in topology"):
        default_pool(codec="int8", codec_below="nvme")
    # codec None/none → no wrapping at all
    p = default_pool(codec="none")
    assert not isinstance(p.tiers["host"].backend, B.CodecBackend)
    p.close()


def test_codec_encoded_pages_survive_n_tier_chain():
    """Every tier below the boundary is wrapped, so a page spilling to
    the bottom of a deep chain stays decodable (an encoded payload can
    never land in a plain tier)."""
    unit = 300
    topo = TierTopology(tiers=(
        TierSpec("l0", kind="numpy", capacity=unit, admit=True),
        TierSpec("l1", kind="numpy", capacity=unit),
        TierSpec("l2", kind="numpy"),
    ))
    p = default_pool(topology=topo, codec="fp8", codec_below="l0")
    x = _rand_page(5, (16, 16))
    for i in range(3):
        p.put(f"k{i}", x, tier="l0")                  # 260 B each encoded
    assert p.tier_of("k0") == "l2"
    y = p.get("k0")
    assert float(jnp.max(jnp.abs(y - x))) < 0.5       # fp8, single hop
    p.close()


# ---------------------------------------------------------------------------
# transfer engine: overlap semantics
# ---------------------------------------------------------------------------


def test_transfer_issued_before_wait_and_overlaps():
    eng = TransferEngine(depth=2, workers=2)

    def slow(v):
        time.sleep(0.15)
        return v

    h1 = eng.submit(lambda: slow(1), key="t1")
    h2 = eng.submit(lambda: slow(2), key="t2")
    # both issued (seq assigned) before anything was waited on
    assert h1.seq < h2.seq
    assert eng.stats.issued == 2
    assert eng.stats.waits_overlapped + eng.stats.waits_blocked == 0
    assert h1.wait() == 1 and h2.wait() == 2
    assert eng.stats.max_in_flight == 2          # genuinely concurrent
    assert eng.stats.completed == 2
    eng.close()


def test_transfer_depth_bounds_in_flight():
    eng = TransferEngine(depth=1, workers=1)
    h1 = eng.submit(lambda: 1)
    h2 = eng.submit(lambda: 2)   # forces retirement of h1 first
    assert h1.done               # double-buffer back-pressure retired it
    assert h2.wait() == 2
    eng.close()


def test_pool_prefetch_returns_wait_handle():
    p = default_pool()
    x = jnp.arange(2048.0)
    p.put("page", x)
    h = p.prefetch("page")
    np.testing.assert_array_equal(np.asarray(h.wait()), np.asarray(x))
    snap = p.snapshot()
    assert snap["transfer"]["issued"] == 1
    assert snap["bytes_fetched"] == x.nbytes


# ---------------------------------------------------------------------------
# plan executor: executed residency == memsim prediction
# ---------------------------------------------------------------------------


def test_executor_residency_matches_memsim_on_planned_graph():
    g = small_graph()
    plan = HyperOffloadPlanner(TPU_V5E).plan(g)
    predicted = memsim.simulate(plan.graph, plan.order)

    pool = default_pool()
    env, trace = OffloadPlanExecutor(plan, pool).run()
    assert trace.usage == predicted.usage          # node-for-node agreement
    assert trace.peak_bytes == predicted.peak_bytes
    assert trace.prefetches > 0                    # the plan really moved data
    snap = pool.snapshot()
    assert snap["bytes_fetched"] > 0 and snap["bytes_stored"] > 0
    assert snap["transfer"]["issued"] == trace.prefetches


def test_executor_values_match_resident_baseline():
    g = small_graph()
    plan = HyperOffloadPlanner(TPU_V5E).plan(g)

    def fn(*args, _n=1):
        s = sum(jnp.sum(a.astype(jnp.float32)) for a in args)
        return tuple(jnp.full((8,), s) + i for i in range(_n))

    fns = {n: (lambda *a, _n=len(node.outputs): fn(*a, _n=_n))
           for n, node in plan.graph.nodes.items() if node.kind == "compute"}
    key = jax.random.key(7)
    inputs = {"x": jax.random.normal(key, (16,))}
    for i in range(4):
        inputs[f"w{i}"] = jnp.full((8,), float(i + 1))

    env, trace = OffloadPlanExecutor(plan, default_pool(), fns).run(inputs)
    ref = run_baseline(g, fns, inputs)
    np.testing.assert_allclose(np.asarray(env["y"]), np.asarray(ref["y"]),
                               rtol=1e-6)
    assert trace.stores >= 1 and trace.detaches >= 1


def test_executor_rejects_invalid_order():
    g = small_graph()
    plan = HyperOffloadPlanner(TPU_V5E).plan(g)
    bad = list(reversed(plan.order))
    with pytest.raises(ValueError):
        OffloadPlanExecutor(plan, default_pool()).run(order=bad)
