"""Runtime memory-pool subsystem: capacity accounting, eviction order,
transfer-engine overlap semantics, backend fallback, and executed-residency
agreement with the compiler's memory simulator."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import small_graph
from repro.core import memsim
from repro.core.costmodel import TPU_V5E
from repro.core.jax_exec import run_baseline
from repro.core.planner import HyperOffloadPlanner
from repro.pool import (
    MemoryPoolManager, OffloadPlanExecutor, PoolCapacityError, TierState,
    TransferEngine, default_pool,
)
from repro.pool import backend as B


def _arr(kb: int, fill: float = 1.0) -> jax.Array:
    return jnp.full((kb * 256,), fill, jnp.float32)   # kb KiB


# ---------------------------------------------------------------------------
# backend probing + fallback
# ---------------------------------------------------------------------------


def test_backend_probe_and_host_roundtrip():
    caps = B.capabilities()
    # the probed host kind must actually be addressable (or None → NumPy)
    if caps.host_kind is not None:
        assert caps.host_kind in caps.memory_kinds
    be = B.make_host_backend()
    x = jnp.arange(512.0)
    h = be.put(x)
    assert be.holds(h)
    np.testing.assert_array_equal(np.asarray(be.get(h)), np.asarray(x))


def test_numpy_backend_is_always_available():
    be = B.NumpyHostBackend()
    x = jnp.arange(64.0).reshape(8, 8)
    h = be.put(x)
    assert isinstance(h, np.ndarray) and be.holds(h)
    y = be.get(h)
    assert isinstance(y, jax.Array)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_to_host_to_device_helpers():
    x = jnp.ones((4, 4), jnp.bfloat16)
    parked = B.to_host(x)
    assert B.is_host_resident(parked)
    back = B.to_device(parked)
    np.testing.assert_array_equal(np.asarray(back, np.float32),
                                  np.asarray(x, np.float32))


# ---------------------------------------------------------------------------
# manager: capacity accounting + eviction
# ---------------------------------------------------------------------------


def test_capacity_accounting_and_drop():
    p = default_pool(host_capacity=1 << 20)
    p.put("a", _arr(64))
    p.put("b", _arr(128))
    used, cap = p.occupancy("host")
    assert used == (64 + 128) * 1024 and cap == 1 << 20
    assert p.snapshot()["bytes_stored"] == used
    p.drop("a")
    assert p.occupancy("host")[0] == 128 * 1024
    assert "a" not in p and "b" in p
    with pytest.raises(KeyError):
        p.get("a")


def test_eviction_spills_lru_lowest_priority_first():
    # host holds exactly 2 × 256 KiB pages; third put must spill one
    p = default_pool(host_capacity=2 * 256 * 1024)
    p.put("old", _arr(256, 1.0))
    p.put("new", _arr(256, 2.0))
    p.get("old")                       # "old" is now more recently used
    p.put("third", _arr(256, 3.0))
    # LRU victim is "new"; it spilled down to the remote tier, not vanished
    assert p.tier_of("new") == "remote" and p.tier_of("old") == "host"
    assert p.tier_of("third") == "host"
    np.testing.assert_array_equal(np.asarray(p.get("new")),
                                  np.asarray(_arr(256, 2.0)))
    assert p.snapshot()["evictions"] == 1

    # planner-priority hints beat recency: low-priority entries go first
    p2 = default_pool(host_capacity=2 * 256 * 1024)
    p2.put("cheap", _arr(256), priority=0.0)
    p2.put("precious", _arr(256), priority=10.0)
    p2.get("cheap")                    # recency would protect "cheap"...
    p2.put("x", _arr(256))
    assert p2.tier_of("cheap") == "remote"      # ...but priority wins
    assert p2.tier_of("precious") == "host"


def test_pinned_entries_never_evict_and_last_tier_overflows():
    host = TierState("host", B.make_host_backend(), capacity=256 * 1024)
    p = MemoryPoolManager([host])      # single tier: nowhere to spill
    p.put("pinned", _arr(256), tier="host", pinned=True)
    with pytest.raises(PoolCapacityError):
        p.put("overflow", _arr(256), tier="host")
    assert p.tier_of("pinned") == "host"


def test_reserve_release_and_headroom():
    """Admission-control ledger: reservations count against the named
    tiers' combined capacity; release returns the headroom."""
    p = default_pool(device_capacity=1 << 20, host_capacity=1 << 20)
    tiers = ("device", "host")
    assert p.headroom(tiers) == 2 << 20
    assert p.reserve("r1", 1 << 20, tiers)
    assert p.reserve("r2", 512 << 10, tiers)
    assert not p.reserve("r3", 1 << 20, tiers)      # would over-commit
    assert p.reserved_bytes(tiers) == (1 << 20) + (512 << 10)
    p.put("a", _arr(256), tier="host")              # occupancy counts too
    assert p.headroom(tiers) == (512 << 10) - 256 * 1024
    p.release("r1")
    assert p.reserve("r3", 1 << 20, tiers)
    p.release("r2")
    p.release("r3")
    p.release("r3")                                  # no-op re-release
    assert p.reserved_bytes() == 0
    assert p.snapshot()["reserved"] == 0
    # an unbounded tier in the set always admits
    assert p.reserve("big", 1 << 40, ("device", "host", "remote"))


def test_evict_listener_fires_on_spill():
    p = default_pool(host_capacity=256 * 1024)
    seen = []
    p.add_evict_listener(lambda entry, dst: seen.append((entry.key, dst)))
    p.put("cold", _arr(256))
    p.put("hot", _arr(256))                          # spills "cold" → remote
    assert seen == [("cold", "remote")]
    assert p.tier_of("cold") == "remote"


def test_shared_pool_across_caches_does_not_collide():
    """The documented shared-pool-across-layers setup: page keys are
    namespaced per cache instance."""
    from repro.offload.kvcache import PagedKVCache

    pool = default_pool()
    b, hkv, d, page = 1, 1, 8, 4
    c1 = PagedKVCache.create(batch=b, max_seq=8, page_size=page,
                             n_kv_heads=hkv, head_dim=d, pool=pool)
    c2 = PagedKVCache.create(batch=b, max_seq=8, page_size=page,
                             n_kv_heads=hkv, head_dim=d, pool=pool)
    ones = jnp.ones((b, page, hkv, d))
    c1.prefill(ones, ones)
    c2.prefill(ones * 7.0, ones * 7.0)
    k1, _ = c1.fetch_pages([0])
    k2, _ = c2.fetch_pages([0])
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(ones)[None])
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(ones * 7.0)[None])


# ---------------------------------------------------------------------------
# transfer engine: overlap semantics
# ---------------------------------------------------------------------------


def test_transfer_issued_before_wait_and_overlaps():
    eng = TransferEngine(depth=2, workers=2)

    def slow(v):
        time.sleep(0.15)
        return v

    h1 = eng.submit(lambda: slow(1), key="t1")
    h2 = eng.submit(lambda: slow(2), key="t2")
    # both issued (seq assigned) before anything was waited on
    assert h1.seq < h2.seq
    assert eng.stats.issued == 2
    assert eng.stats.waits_overlapped + eng.stats.waits_blocked == 0
    assert h1.wait() == 1 and h2.wait() == 2
    assert eng.stats.max_in_flight == 2          # genuinely concurrent
    assert eng.stats.completed == 2
    eng.close()


def test_transfer_depth_bounds_in_flight():
    eng = TransferEngine(depth=1, workers=1)
    h1 = eng.submit(lambda: 1)
    h2 = eng.submit(lambda: 2)   # forces retirement of h1 first
    assert h1.done               # double-buffer back-pressure retired it
    assert h2.wait() == 2
    eng.close()


def test_pool_prefetch_returns_wait_handle():
    p = default_pool()
    x = jnp.arange(2048.0)
    p.put("page", x)
    h = p.prefetch("page")
    np.testing.assert_array_equal(np.asarray(h.wait()), np.asarray(x))
    snap = p.snapshot()
    assert snap["transfer"]["issued"] == 1
    assert snap["bytes_fetched"] == x.nbytes


# ---------------------------------------------------------------------------
# plan executor: executed residency == memsim prediction
# ---------------------------------------------------------------------------


def test_executor_residency_matches_memsim_on_planned_graph():
    g = small_graph()
    plan = HyperOffloadPlanner(TPU_V5E).plan(g)
    predicted = memsim.simulate(plan.graph, plan.order)

    pool = default_pool()
    env, trace = OffloadPlanExecutor(plan, pool).run()
    assert trace.usage == predicted.usage          # node-for-node agreement
    assert trace.peak_bytes == predicted.peak_bytes
    assert trace.prefetches > 0                    # the plan really moved data
    snap = pool.snapshot()
    assert snap["bytes_fetched"] > 0 and snap["bytes_stored"] > 0
    assert snap["transfer"]["issued"] == trace.prefetches


def test_executor_values_match_resident_baseline():
    g = small_graph()
    plan = HyperOffloadPlanner(TPU_V5E).plan(g)

    def fn(*args, _n=1):
        s = sum(jnp.sum(a.astype(jnp.float32)) for a in args)
        return tuple(jnp.full((8,), s) + i for i in range(_n))

    fns = {n: (lambda *a, _n=len(node.outputs): fn(*a, _n=_n))
           for n, node in plan.graph.nodes.items() if node.kind == "compute"}
    key = jax.random.key(7)
    inputs = {"x": jax.random.normal(key, (16,))}
    for i in range(4):
        inputs[f"w{i}"] = jnp.full((8,), float(i + 1))

    env, trace = OffloadPlanExecutor(plan, default_pool(), fns).run(inputs)
    ref = run_baseline(g, fns, inputs)
    np.testing.assert_allclose(np.asarray(env["y"]), np.asarray(ref["y"]),
                               rtol=1e-6)
    assert trace.stores >= 1 and trace.detaches >= 1


def test_executor_rejects_invalid_order():
    g = small_graph()
    plan = HyperOffloadPlanner(TPU_V5E).plan(g)
    bad = list(reversed(plan.order))
    with pytest.raises(ValueError):
        OffloadPlanExecutor(plan, default_pool()).run(order=bad)
