"""The `repro.obs` telemetry subsystem: tracer ring semantics, Chrome
trace export + schema checker, metrics registry / Prometheus exposition,
the overlap analyzer's hidden-vs-exposed decomposition and its exact
agreement with `TransferStats`, and the front-door wiring (telemetry on:
one shared tracer, lifecycle instants, latency histograms; telemetry off:
zero events, `session.stats()` unchanged in shape, identical tokens)."""

import json
import time

import jax
import numpy as np
import pytest

from repro.api import HyperOffloadSession, OffloadConfig
from repro.api.config import TelemetryConfig
from repro.api.session import _weighted_plan_lead
from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.obs import (
    NULL_TRACER, MetricsRegistry, OverlapAnalyzer, TraceEvent, Tracer,
)
from repro.obs.check import validate_events, validate_file
from repro.pool.transfer import TransferEngine
from repro.sched import Request

CFG = REGISTRY["phi3-mini-3.8b"].reduced()


@pytest.fixture(scope="module")
def model_and_params():
    m = build_model(CFG)
    return m, m.init(jax.random.key(0))


def _trace(requests=3, **telemetry):
    # chunk_size=6 (not 8): test_sched's compile-count test asserts a
    # jit-cache DELTA for chunk_size=8, and the chunk entry point is
    # cached per model config, shared across test modules.
    return OffloadConfig(
        mode="kv_offload", max_batch=2, max_seq=32, chunk_size=6,
        telemetry=TelemetryConfig(enable=True, **telemetry))


def _run_requests(session, model_and_params, n=3):
    """Run n requests; outputs keyed by submission index (req_ids come
    from a global counter, so they differ run to run)."""
    model, params = model_and_params
    sched = session.scheduler(model, params)
    reqs = [Request(tokens=np.arange(4 + 2 * i) % CFG.vocab_size,
                    max_new_tokens=3, seed=i) for i in range(n)]
    out = sched.run(reqs)
    return {i: out[r.req_id] for i, r in enumerate(reqs)}, sched


# ---------------------------------------------------------------------------
# tracer ring
# ---------------------------------------------------------------------------


def test_ring_eviction_keeps_newest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("t", f"e{i}")
    evs = tr.events()
    assert [e.name for e in evs] == ["e6", "e7", "e8", "e9"]
    assert tr.dropped == 6
    assert tr.snapshot() == {"events": 4, "dropped": 6, "capacity": 4}


def test_span_end_ge_start():
    tr = Tracer()
    with tr.span("t", "work", tag=1):
        time.sleep(0.001)
    (ev,) = tr.events()
    assert ev.ph == "X" and ev.end >= ev.ts and ev.dur >= 0.001
    assert ev.args == {"tag": 1}
    # a negative duration fed directly is clamped, never exported
    tr.complete("t", "clamped", tr.now(), -1.0)
    assert tr.events()[-1].dur == 0.0


def test_exported_trace_is_valid_chrome_json(tmp_path):
    tr = Tracer()
    with tr.span("sched", "step", step=0):
        tr.instant("request", "QUEUED", {"req": 1})
    path = str(tmp_path / "trace.json")
    tr.export(path)
    with open(path) as f:
        obj = json.load(f)
    assert validate_events(obj) == []
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert "M" in phases and "X" in phases and "i" in phases
    # timestamps are rebased to the tracer epoch in microseconds
    data_events = [e for e in obj["traceEvents"] if e["ph"] != "M"]
    assert all(e["ts"] >= 0 for e in data_events)


def test_null_tracer_emits_nothing():
    nt = NULL_TRACER
    assert nt.enabled is False
    nt.instant("t", "x")
    nt.complete("t", "x", 0.0, 1.0)
    with nt.span("t", "x", a=1):
        pass
    assert nt.events() == [] and len(nt) == 0


# ---------------------------------------------------------------------------
# schema checker rejects corrupt traces
# ---------------------------------------------------------------------------


def test_checker_rejects_corrupt_traces():
    assert validate_events([1, 2]) != []
    assert validate_events({"nope": []}) != []
    bad_ph = {"traceEvents": [
        {"name": "x", "ph": "Q", "ts": 0, "pid": 1, "tid": 0}]}
    assert any("ph" in e for e in validate_events(bad_ph))
    neg_dur = {"traceEvents": [
        {"name": "x", "ph": "X", "ts": 5.0, "dur": -2.0, "pid": 1,
         "tid": 0}]}
    assert any("end < start" in e for e in validate_events(neg_dur))
    empty = {"traceEvents": [
        {"name": "x", "ph": "i", "ts": 0, "pid": 1, "tid": 0, "s": "t"}]}
    assert any("no complete spans" in e for e in validate_events(empty))


def test_checker_wait_ordering():
    def span(name, ts, dur, args):
        return {"name": name, "cat": "transfer", "ph": "X", "ts": ts,
                "dur": dur, "pid": 1, "tid": 0, "args": args}
    # a wait must never resolve before its transfer completes
    obj = {"traceEvents": [
        span("transfer", 1000.0, 500.0, {"seq": 1}),
        span("transfer.wait", 100.0, 50.0, {"seq": 1, "hit": False}),
    ]}
    errs = validate_events(obj)
    assert any("before its transfer completed" in e for e in errs)
    # an overlapped (hit) wait must start after the transfer completed
    obj = {"traceEvents": [
        span("transfer", 1000.0, 500.0, {"seq": 1}),
        span("transfer.wait", 1200.0, 400.0, {"seq": 1, "hit": True}),
    ]}
    errs = validate_events(obj)
    assert any("before the transfer completed" in e for e in errs)
    # a BLOCKED wait starting before the transfer span is legal: the span
    # covers execution only, so queue time puts wait-start ahead of it
    obj = {"traceEvents": [
        span("transfer", 1000.0, 500.0, {"seq": 1}),
        span("transfer.wait", 100.0, 1400.0, {"seq": 1, "hit": False}),
    ]}
    assert validate_events(obj) == []


def test_checker_validate_file_unreadable(tmp_path):
    p = tmp_path / "junk.json"
    p.write_text("{not json")
    assert any("not readable" in e for e in validate_file(str(p)))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_instruments_and_prometheus():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", help="requests")
    c.inc()
    c.inc(2)
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.counter("reqs_total") is c      # idempotent getter
    reg.gauge("depth").set(7)
    h = reg.histogram("lat", (1, 2, 4))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["buckets"] == {1: 1, 2: 2, 4: 3}
    assert snap["sum"] == pytest.approx(105.0)
    with pytest.raises(ValueError):
        reg.histogram("lat", (1, 2, 8))        # bucket mismatch
    reg.register_collector("pool", lambda: {"puts": 3, "tier": {"used": 9},
                                            "name": "host", "ok": True})
    text = reg.render_prometheus()
    assert "# TYPE reqs_total counter" in text
    assert "reqs_total 3" in text
    assert 'lat_bucket{le="4"} 3' in text
    assert 'lat_bucket{le="+Inf"} 4' in text
    assert "pool_puts 3" in text and "pool_tier_used 9" in text
    # strings and bools never become samples
    assert "pool_name" not in text and "pool_ok" not in text
    assert reg.collect() == {"pool": {"puts": 3, "tier": {"used": 9},
                                      "name": "host", "ok": True}}


def test_histogram_requires_ascending_buckets():
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("bad", (4, 2, 1))
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("bad", ())


# ---------------------------------------------------------------------------
# plan-lead aggregation (the stats() weighting fix)
# ---------------------------------------------------------------------------


def test_weighted_plan_lead():
    # a 1-step scheduler must not pull a 99-step scheduler's figure toward
    # itself the way the old unweighted mean of means did
    assert _weighted_plan_lead([(99, 2.0), (1, 10.0)]) == \
        pytest.approx((99 * 2.0 + 10.0) / 100)
    assert _weighted_plan_lead([(0, 3.0), (0, 5.0)]) == pytest.approx(4.0)
    assert _weighted_plan_lead([(5, 1.5)]) == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# overlap analyzer on synthetic traces
# ---------------------------------------------------------------------------


def _transfer_events():
    """Two transfers: seq 1 waited-blocked (0.2s exposed of 1.0 inflight),
    seq 2 never waited (fully hidden, 0.5s), plus one sched step span
    containing the wait."""
    return [
        TraceEvent("sched", "step", "X", 0.0, 2.0, args={"step": 0}),
        TraceEvent("transfer", "transfer", "X", 0.0, 1.0,
                   args={"seq": 1, "src": "host", "dst": "device"}),
        TraceEvent("transfer", "transfer.wait", "X", 0.8, 0.2,
                   args={"seq": 1, "hit": False}),
        TraceEvent("transfer", "transfer", "X", 0.5, 0.5,
                   args={"seq": 2, "src": "remote", "dst": "device"}),
    ]


def test_overlap_decomposition():
    rep = OverlapAnalyzer(_transfer_events()).report()
    assert rep["transfers"] == 2
    assert rep["waits_blocked"] == 1 and rep["waits_overlapped"] == 0
    assert rep["exposed_s"] == pytest.approx(0.2)
    assert rep["hidden_s"] == pytest.approx(0.8 + 0.5)
    assert rep["hidden_fraction"] == pytest.approx(1.3 / 1.5)
    assert rep["inflight_s"] == pytest.approx(1.5)
    assert rep["by_tier"]["host->device"]["exposed_s"] == pytest.approx(0.2)
    assert rep["by_tier"]["remote->device"]["hidden_fraction"] == 1.0
    # both transfers land in step 0 (wait time / issue time attribution)
    (step0,) = rep["by_step"]
    assert step0["step"] == 0 and step0["transfers"] == 2


def test_overlap_validate_against_stats():
    an = OverlapAnalyzer(_transfer_events())
    good = {"waits_overlapped": 0, "waits_blocked": 1, "blocked_s": 0.2}
    assert an.validate(good) == []
    bad = {"waits_overlapped": 3, "waits_blocked": 1, "blocked_s": 0.9}
    errs = an.validate(bad)
    assert any("waits_overlapped" in e for e in errs)
    assert any("blocked_s" in e for e in errs)


def test_overlap_orphan_waits():
    evs = [TraceEvent("transfer", "transfer.wait", "X", 0.8, 0.2,
                      args={"seq": 99, "hit": False})]
    an = OverlapAnalyzer(evs)
    assert an.orphan_waits == 1
    # with ring drops only the total wait count can be checked
    assert an.validate({"waits_overlapped": 1, "waits_blocked": 0,
                        "blocked_s": 0.0}) == []
    errs = an.validate({"waits_overlapped": 5, "waits_blocked": 2,
                        "blocked_s": 0.0})
    assert any("total waits" in e for e in errs)


def test_overlap_hidden_fraction_none_without_time():
    assert OverlapAnalyzer([]).report()["hidden_fraction"] is None


# ---------------------------------------------------------------------------
# per-handle ordering through a real TransferEngine
# ---------------------------------------------------------------------------


def test_transfer_engine_handle_ordering():
    tr = Tracer()
    eng = TransferEngine(depth=4, tracer=tr)
    try:
        h_slow = eng.submit(lambda: time.sleep(0.01) or "a", key="slow",
                            src="host", dst="device")
        h_fast = eng.submit(lambda: "b", key="fast")
        time.sleep(0.05)          # let 'fast' complete before its wait
        assert h_fast.wait() == "b" and h_slow.wait() == "a"
        h_fast.wait()             # idempotent: no second wait span
    finally:
        eng.close()
    evs = tr.events()
    transfers = {e.args["seq"]: e for e in evs if e.name == "transfer"}
    waits = {e.args["seq"]: e for e in evs if e.name == "transfer.wait"}
    assert len(transfers) == 2 and len(waits) == 2
    assert waits[h_fast.seq].args["hit"] is True
    eps = 1e-4
    for seq, w in waits.items():
        t = transfers[seq]
        assert t.end >= t.ts                      # issue <= complete
        assert w.end + eps >= t.end               # wait resolves after done
        assert w.ts + eps >= t.ts                 # wait starts after issue
    # the trace's exposed time IS blocked_s — same measurement, recorded
    # once — so the agreement is exact, not approximate
    errs = OverlapAnalyzer(evs).validate(eng.stats.snapshot(), tol_s=1e-9)
    assert errs == []


# ---------------------------------------------------------------------------
# front-door wiring (session-level, tiny model)
# ---------------------------------------------------------------------------


def test_session_telemetry_end_to_end(model_and_params, tmp_path):
    path = str(tmp_path / "trace.json")
    with HyperOffloadSession(_trace(trace_path=path)) as s:
        out, sched = _run_requests(s, model_and_params)
        st = s.stats()
        # the overlap decomposition agrees with the engine's own counters
        errs = OverlapAnalyzer.from_tracer(s.tracer).validate(
            s.pool.snapshot()["transfer"])
        assert errs == []
        rep = s.overlap()
        assert rep["transfers"] > 0 and rep["hidden_fraction"] is not None
        # request lifecycle instants: one full QUEUED→…→DONE per request
        names = [e.name for e in s.tracer.events() if e.cat == "request"]
        for name in ("QUEUED", "PREFILL", "DECODE", "DONE"):
            assert names.count(name) == len(out)
        # step phases + pool traffic + per-request histograms all present
        cats = {(e.cat, e.name) for e in s.tracer.events()}
        assert ("sched", "step") in cats and ("pool", "put") in cats
        hists = st["telemetry"]["histograms"]["histograms"]
        assert hists["req_ttft_steps"]["count"] == len(out)
        assert hists["req_queue_wait_steps"]["count"] == len(out)
        assert "req_ttft_steps_bucket" in s.stats_text()
    # close() exported to telemetry.trace_path; the file passes the checker
    assert validate_file(path) == []


def test_session_disabled_shape_and_tokens(model_and_params):
    outs = {}
    for enable in (False, True):
        cfg = OffloadConfig(mode="kv_offload", max_batch=2, max_seq=32,
                            chunk_size=6,
                            telemetry=TelemetryConfig(enable=enable))
        with HyperOffloadSession(cfg) as s:
            out, _ = _run_requests(s, model_and_params)
            outs[enable] = {k: list(v) for k, v in out.items()}
            st = s.stats()
            if enable:
                assert "telemetry" in st
            else:
                assert "telemetry" not in st
                assert s.tracer is NULL_TRACER and s.tracer.events() == []
                assert set(st) == {"mode", "pool", "serve", "sched",
                                   "paged", "prefix", "plans_cached"}
                with pytest.raises(RuntimeError):
                    s.export_trace("/tmp/never.json")
                assert s.overlap() is None
    # telemetry is observation only: emitted tokens are identical
    assert outs[False] == outs[True]


def test_session_slo_counters_in_stats_and_prometheus(model_and_params):
    """SLO counters flow end to end: scheduler → session collector →
    ``stats()['sched']`` → the Prometheus text dump, all agreeing — and
    the preempt/resume lifecycle lands in the trace ring as instants."""
    from repro.slo import SLOConfig, SLOSpec

    model, params = model_and_params
    vocab = REGISTRY["phi3-mini-3.8b"].reduced().vocab_size
    rng = np.random.default_rng(9)
    reqs = [
        Request(tokens=rng.integers(0, vocab, 5, dtype=np.int32),
                max_new_tokens=10, arrival=0.0, seed=0,
                slo=SLOSpec("batch")),
        Request(tokens=rng.integers(0, vocab, 4, dtype=np.int32),
                max_new_tokens=3, arrival=3.0, seed=1,
                slo=SLOSpec("interactive", ttft_deadline=2.0)),
    ]
    cfg = OffloadConfig(mode="continuous", max_batch=1, max_seq=32,
                        slo=SLOConfig(enable=True),
                        telemetry=TelemetryConfig(enable=True))
    with HyperOffloadSession(cfg) as s:
        sched = s.scheduler(model, params)
        sched.run(reqs)
        st = s.stats()["sched"]
        assert st["preemptions"] == 1 and st["resumes"] == 1
        assert st["shed"] == 0
        assert st["slo"]["goodput_tokens"] == 13
        text = s.stats_text()
        # the flattened collector samples mirror the snapshot numerically
        for line in ("sched_preemptions 1", "sched_resumes 1",
                     "sched_shed 0", "sched_slo_goodput_tokens 13",
                     "sched_slo_met_requests 2"):
            assert line in text, f"{line!r} missing from Prometheus dump"
        # the deadline-relative slack histogram saw the interactive request
        assert "req_ttft_slack_steps_bucket" in text
        # preempt/restore are first-class trace events
        names = [e.name for e in s.tracer.events() if e.cat == "request"]
        assert names.count("PREEMPTED") == 1 and names.count("RESUMED") == 1


def test_telemetry_config_round_trip():
    cfg = OffloadConfig(telemetry=TelemetryConfig(
        enable=True, ring_capacity=128, trace_path="/tmp/t.json"))
    again = OffloadConfig.from_dict(
        json.loads(json.dumps(cfg.to_dict(), default=str)))
    assert again.telemetry == cfg.telemetry
    with pytest.raises(ValueError):
        TelemetryConfig(ring_capacity=0)
