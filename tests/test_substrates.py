"""Optimizer, data pipeline, checkpointing, sharding rules, jax_exec."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.core.ir import Graph
from repro.core.jax_exec import PlanExecutor, run_baseline
from repro.core.planner import HyperOffloadPlanner
from repro.core.costmodel import TPU_V5E
from repro.data.pipeline import SyntheticTokens
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.sharding.rules import DEFAULT_RULES, logical_spec
from repro.launch.mesh import make_debug_mesh


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    w = {"w": jnp.array([5.0, -3.0, 2.0])}
    st_ = adamw_init(w)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
        w, st_ = adamw_update(g, st_, w, 0.05, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.1


def test_adamw_grad_clip():
    w = {"w": jnp.ones((4,))}
    st_ = adamw_init(w)
    g = {"w": jnp.full((4,), 1e6)}
    w2, st2 = adamw_update(g, st_, w, 0.1, grad_clip=1.0, weight_decay=0.0)
    # clipped: update magnitude bounded by lr * O(1)
    assert float(jnp.max(jnp.abs(w2["w"] - w["w"]))) < 0.2


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100)) == pytest.approx(1.0)
    end = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100, floor=0.1))
    assert end == pytest.approx(0.1, abs=1e-5)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_synthetic_tokens_deterministic_and_shifted():
    d = SyntheticTokens(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    b1, b2 = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(d.batch(6)["tokens"]),
                              np.asarray(b1["tokens"]))
    # targets are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["targets"][:, :-1]))
    assert int(b1["tokens"].max()) < 97


def test_synthetic_learnable_structure():
    """Most transitions follow the fixed permutation."""
    d = SyntheticTokens(vocab_size=50, seq_len=64, global_batch=8, noise=0.1)
    b = d.batch(0)
    toks, tgts = np.asarray(b["tokens"]), np.asarray(b["targets"])
    # the same current token maps to the same next token (mod noise)
    from collections import Counter, defaultdict
    votes = defaultdict(Counter)
    for row_t, row_y in zip(toks, tgts):
        for t, y in zip(row_t, row_y):
            votes[t][y] += 1
    agree = sum(c.most_common(1)[0][1] for c in votes.values())
    total = sum(sum(c.values()) for c in votes.values())
    assert agree / total > 0.8


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree, step=42)
    restored, step = load_checkpoint(path, tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(path, {"a": jnp.ones((3, 3))})


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_logical_spec_divisibility_drop():
    mesh = make_debug_mesh((1, 1))
    # kv=8 over a 16-wide model axis must drop (simulated by size-1 mesh —
    # use the pure arithmetic path with explicit mesh shape instead)
    from jax.sharding import PartitionSpec as P
    spec = logical_spec((8, 64), ("kv_heads", None), DEFAULT_RULES, mesh)
    assert spec == P("model", None) or spec == P(None, None)


def test_logical_spec_no_repeated_axes():
    mesh = make_debug_mesh((1, 1))
    spec = logical_spec((16, 16, 16), ("embed", "embed", "embed"),
                        DEFAULT_RULES, mesh)
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))


@given(st.lists(st.integers(1, 512), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_logical_spec_always_valid_partitionspec(dims):
    names = ["batch", "embed", "mlp", "heads"][: len(dims)]
    spec = logical_spec(dims, names, DEFAULT_RULES, make_debug_mesh((1, 1)))
    assert len(spec) <= len(dims)


# ---------------------------------------------------------------------------
# Plan executor on real arrays
# ---------------------------------------------------------------------------


def test_plan_executor_equivalence_with_offload():
    D = 64
    g = Graph()
    g.add_tensor("x", D * D * 4)
    fns, inputs = {}, {}
    prev = "x"
    for i in range(5):
        g.add_tensor(f"w{i}", 64 << 20, "weight", "remote")
        g.add_tensor(f"h{i}", D * D * 4)
        g.compute(f"f{i}", inputs=(prev, f"w{i}"), outputs=(f"h{i}",),
                  flops=1e12, hbm_bytes=1e6)
        fns[f"f{i}"] = lambda x, w: (jnp.tanh(x @ w[:D, :D]),)
        inputs[f"w{i}"] = 0.1 * jax.random.normal(jax.random.key(i), (D, D))
        prev = f"h{i}"
    inputs["x"] = jax.random.normal(jax.random.key(9), (D, D))

    plan = HyperOffloadPlanner(TPU_V5E).plan(g)
    assert any(n.kind == "prefetch" for n in plan.graph.nodes.values())
    # PlanExecutor is a sync wrapper over the pool executor: inject a pool
    # and confirm the cache ops really routed through it
    from repro.pool import default_pool
    pool = default_pool()
    out = PlanExecutor(plan.graph, fns, pool=pool).run(inputs, plan.order)
    ref = run_baseline(g, fns, inputs)
    np.testing.assert_allclose(np.asarray(out["h4"]), np.asarray(ref["h4"]),
                               atol=1e-6)
    snap = pool.snapshot()
    assert snap["puts"] >= 5 and snap["bytes_fetched"] > 0
    assert snap["transfer"]["issued"] > 0     # prefetches went async
    # sync contract: a run leaves nothing behind in an injected pool
    assert snap["tier/host"]["entries"] == 0
    pool.close()


def test_plan_executor_rejects_missing_fn():
    g = Graph()
    g.add_tensor("a", 8)
    g.compute("f", outputs=("a",))
    with pytest.raises(ValueError, match="no compute fn"):
        PlanExecutor(g, {})


# ---------------------------------------------------------------------------
# Gradient accumulation
# ---------------------------------------------------------------------------


def test_grad_accum_matches_full_batch():
    """grad_accum=4 must match the single-shot step to fp32 tolerance."""
    import jax.numpy as jnp
    from repro.configs import REGISTRY
    from repro.models.model import build_model
    from repro.training.step import (TrainStepConfig, init_train_state,
                                     make_train_step)

    cfg = REGISTRY["phi3-mini-3.8b"].reduced()
    m = build_model(cfg)
    data = SyntheticTokens(cfg.vocab_size, seq_len=24, global_batch=8, noise=0.05)
    out = {}
    for ga in (1, 4):
        ts = TrainStepConfig(warmup=2, total_steps=4, peak_lr=1e-3, grad_accum=ga)
        params, opt = init_train_state(m, jax.random.key(0), ts=ts)
        step = make_train_step(m, ts)
        for i in range(4):
            params, opt, metrics = step(params, opt, data.batch(i))
        out[ga] = (params, float(metrics["loss"]))
    assert out[1][1] == pytest.approx(out[4][1], abs=1e-4)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(out[1][0]),
                              jax.tree.leaves(out[4][0])))
    assert err < 1e-4
