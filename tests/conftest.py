"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real (single) device; only launch/dryrun.py forces 512."""

import jax
import pytest


def hypothesis_or_stub():
    """Returns (given, settings, st). With hypothesis installed these are
    the real objects; without it, stand-ins that turn each property test
    into a clean skip instead of a collection error."""
    try:
        from hypothesis import given, settings
        import hypothesis.strategies as st
        return given, settings, st
    except ImportError:
        pass

    class _StrategiesStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    def given(*a, **k):
        return pytest.mark.skip(
            reason="hypothesis not installed; property test skipped")

    def settings(*a, **k):
        return lambda fn: fn

    return given, settings, _StrategiesStub()


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def small_graph():
    """A 4-layer chain with remote weights + an offloadable activation gap,
    shared by core tests."""
    from repro.core.ir import Graph
    g = Graph()
    g.add_tensor("x", 1 << 20)
    prev = "x"
    for i in range(4):
        g.add_tensor(f"w{i}", 64 << 20, "weight", "remote")
        g.add_tensor(f"h{i}", 1 << 20)
        g.compute(f"f{i}", inputs=(prev, f"w{i}"), outputs=(f"h{i}",),
                  flops=5e11, hbm_bytes=1e6)
        prev = f"h{i}"
    # an activation produced early and consumed late (offload candidate)
    g.add_tensor("skip", 128 << 20)
    g.nodes["f0"].outputs = ("h0", "skip")
    g.add_tensor("y", 1 << 20)
    g.compute("tail", inputs=("h3", "skip"), outputs=("y",),
              flops=5e11, hbm_bytes=1e6)
    return g
