"""Pallas kernels vs pure-jnp oracles: shape/dtype/flag sweeps in
interpret mode (CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (
    decode_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    paged_decode_attention_ref,
    ssd_scan_ref,
)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 64, 32),
    (1, 4, 4, 96, 64),
    (2, 8, 1, 33, 16),     # ragged seq (padding path)
    (1, 2, 2, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, hq, hkv, s, d, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d)).astype(dtype)
    out = flash_attention_pallas(q, k, v, scale=d ** -0.5, block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window,cap,causal", [
    (None, None, True),
    (32, None, True),
    (None, 30.0, True),
    (16, 50.0, True),
    (None, None, False),
])
def test_flash_attention_flags(window, cap, causal):
    b, hq, hkv, s, d = 2, 4, 2, 80, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    out = flash_attention_pallas(q, k, v, scale=0.2, causal=causal,
                                 window=window, logit_cap=cap,
                                 block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, scale=0.2, causal=causal,
                              window=window, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,c,d,pos", [
    (2, 4, 2, 64, 32, 5),
    (2, 4, 2, 64, 32, 63),
    (2, 4, 2, 64, 32, 200),   # wrapped ring
    (1, 8, 8, 100, 16, 99),
    (3, 6, 1, 48, 64, 20),
])
def test_decode_attention(b, hq, hkv, c, d, pos):
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, c, d))
    v = jax.random.normal(ks[2], (b, hkv, c, d))
    out = decode_attention_pallas(q, k, v, jnp.int32(pos), scale=d ** -0.5,
                                  block_k=32)
    ref = decode_attention_ref(q, k, v, jnp.int32(pos), scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# paged decode: page-table-driven kernel vs gather ref
# ---------------------------------------------------------------------------


def _paged_inputs(key, b, hq, hkv, d, page, n_pool):
    ks = jax.random.split(jax.random.key(key), 5)
    q = jax.random.normal(ks[0], (b, hq, d))
    kp = jax.random.normal(ks[1], (n_pool, b, page, hkv, d))
    vp = jax.random.normal(ks[2], (n_pool, b, page, hkv, d))
    kt = jax.random.normal(ks[3], (b, page, hkv, d))
    vt = jax.random.normal(ks[4], (b, page, hkv, d))
    return q, kp, vp, kt, vt


@pytest.mark.parametrize("hq,hkv", [(2, 2), (8, 2), (4, 1)])   # GQA groups
@pytest.mark.parametrize("cap", [None, 30.0])
def test_paged_decode_gqa_and_softcap(hq, hkv, cap):
    b, d, page = 2, 32, 8
    q, kp, vp, kt, vt = _paged_inputs(10, b, hq, hkv, d, page, 5)
    table = jnp.asarray([3, 0, 4], jnp.int32)     # scrambled, non-contiguous
    args = (q, kp, vp, table, kt, vt, jnp.int32(5))
    out = paged_decode_attention_pallas(*args, scale=d ** -0.5, logit_cap=cap)
    ref = paged_decode_attention_ref(*args, scale=d ** -0.5, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("table,tail_len", [
    ((0, 1, 2, 3, 4), 5),   # all pages, partial tail
    ((2, 4), 0),            # tail empty
    ((1, 3), 8),            # tail exactly full (just-flushed boundary)
    ((), 3),                # tail-only attention (no pages yet)
    ((), 1),                # single-token tail
])
def test_paged_decode_tail_boundaries(table, tail_len):
    """Ring-slot validity at the page boundary (ISSUE satellite): the
    fused kernel must reproduce the two-segment merged softmax when the
    tail is empty, partial, and exactly full."""
    b, hq, hkv, d, page = 2, 4, 2, 32, 8
    q, kp, vp, kt, vt = _paged_inputs(11, b, hq, hkv, d, page, 5)
    args = (q, kp, vp, jnp.asarray(table, jnp.int32), kt, vt,
            jnp.int32(tail_len))
    out = paged_decode_attention_pallas(*args, scale=d ** -0.5)
    ref = paged_decode_attention_ref(*args, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_decode_ref_is_bitwise_the_gather_path():
    """The lowering-free ref path IS the legacy gather/concat math — this
    identity is what makes codec-"none" fused serving token-identical."""
    from repro.offload.kvcache import _paged_attend
    b, hq, hkv, d, page = 2, 4, 2, 32, 8
    q, kp, vp, kt, vt = _paged_inputs(12, b, hq, hkv, d, page, 6)
    for table, tl in [((5, 1, 2), 4), ((0,), 0), ((), 7)]:
        t = jnp.asarray(table, jnp.int32)
        ref = paged_decode_attention_ref(q, kp, vp, t, kt, vt,
                                         jnp.int32(tl), scale=d ** -0.5)
        gather = _paged_attend(q, kp[t], vp[t], kt, vt, jnp.int32(tl),
                               d ** -0.5)
        assert bool(jnp.all(ref == gather))


@pytest.mark.parametrize("pos", [63, 64, 65, 95, 96, 200])
def test_decode_attention_ring_wrap_mid_block(pos):
    """Ring wrap regression (ISSUE satellite): positions at, just past,
    and mid-way through block boundaries of the ring cache, where the
    validity mask wraps inside a kv block."""
    b, hq, hkv, c, d = 2, 4, 2, 64, 32
    ks = jax.random.split(jax.random.key(13), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    k = jax.random.normal(ks[1], (b, hkv, c, d))
    v = jax.random.normal(ks[2], (b, hkv, c, d))
    out = decode_attention_pallas(q, k, v, jnp.int32(pos), scale=d ** -0.5,
                                  block_k=32)
    ref = decode_attention_ref(q, k, v, jnp.int32(pos), scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_decode_ops_wrapper_jits():
    b, hq, hkv, d, page = 1, 4, 2, 16, 8
    q, kp, vp, kt, vt = _paged_inputs(14, b, hq, hkv, d, page, 3)
    t = jnp.asarray([1, 2], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, t, kt, vt, jnp.int32(2),
                                     scale=d ** -0.5)
    ref = paged_decode_attention_ref(q, kp, vp, t, kt, vt, jnp.int32(2),
                                     scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
    (2, 64, 8, 16, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.1
    bm = jax.random.normal(ks[2], (b, s, h, n)) * 0.3
    cm = jax.random.normal(ks[3], (b, s, h, n)) * 0.3
    y, st = ops.ssd_scan(x, a, bm, cm, chunk)
    yr, sr = ssd_scan_ref(x, a, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), atol=3e-5, rtol=1e-4)


def test_model_level_pallas_equivalence():
    from repro.configs import REGISTRY
    from repro.models.model import build_model
    from repro.models.runtime import use_attention_impl

    for name in ("gemma2-9b", "mamba2-370m"):
        cfg = REGISTRY[name].reduced()
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
        l1, _ = m.forward(params, {"tokens": toks, "targets": toks})
        with use_attention_impl("pallas"):
            l2, _ = m.forward(params, {"tokens": toks, "targets": toks})
        assert float(jnp.max(jnp.abs(l1 - l2))) < 5e-5
