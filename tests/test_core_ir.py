"""IR, lifetime analysis, and memory-simulator unit tests."""

import pytest

from repro.core import lifetime, memsim
from repro.core.ir import Graph, Node

from conftest import small_graph


def test_graph_construction_and_order():
    g = small_graph()
    order = g.order()
    assert order[0] == "f0" and order[-1] == "tail"
    # remote-initial weights are unreadable without prefetch
    with pytest.raises(ValueError, match="non-resident"):
        g.validate_order(order)
    # the everything-resident baseline validates
    g.residentize().validate_order(order)


def test_validate_rejects_remote_read():
    g = Graph()
    g.add_tensor("w", 10, "weight", "remote")
    g.add_tensor("y", 10)
    g.compute("f", inputs=("w",), outputs=("y",))
    with pytest.raises(ValueError, match="non-resident"):
        g.validate_order(g.order())
    g2 = Graph()
    g2.add_tensor("w", 10, "weight", "remote")
    g2.add_tensor("y", 10)
    g2.prefetch("w")
    g2.compute("f", inputs=("w",), outputs=("y",))
    g2.validate_order(g2.order())  # ok with prefetch


def test_validate_rejects_detach_then_read():
    g = Graph()
    g.add_tensor("a", 10)
    g.add_tensor("b", 10)
    g.add_tensor("c", 10)
    g.compute("f", outputs=("a",))
    g.store("a")
    g.detach("a")
    g.compute("g", inputs=("a",), outputs=("b",))
    with pytest.raises(ValueError, match="non-resident"):
        g.validate_order(g.order())


def test_detach_without_store_rejected_by_memsim_semantics():
    g = Graph()
    g.add_tensor("a", 10)
    g.compute("f", outputs=("a",))
    g.detach("a")
    g.validate_order(g.order())  # detach of dead tensor is legal


def test_lifetime_gaps():
    g = small_graph()
    lt = lifetime.analyze(g)
    skip = lt["skip"]
    assert skip.producer_pos == 0
    assert skip.use_positions == (4,)   # consumed by "tail"
    g0, g1 = skip.longest_gap()
    assert (g0, g1) == (0, 4)
    # weights have no producer
    assert lt["w0"].producer_pos is None
    assert lt["w0"].free_pos is None  # persistent


def test_memsim_peak_and_residentize():
    g = small_graph().residentize()
    tr = memsim.simulate(g)
    # everything resident: 4 weights + skip + live activations
    assert tr.peak_bytes >= 4 * (64 << 20) + (128 << 20)
    # events alternate allocs/frees and cover all activations
    allocs = [t for _, op, t in tr.events if op == "alloc"]
    assert "skip" in allocs


def test_memsim_detach_reduces_peak():
    g = Graph()
    g.add_tensor("w", 100, "weight")
    g.add_tensor("a", 1000)
    g.add_tensor("b", 10)
    g.compute("f", inputs=("w",), outputs=("a",))
    g.compute("g", inputs=("a",), outputs=("b",))
    base = memsim.simulate(g).peak_bytes

    g2 = Graph()
    g2.add_tensor("w", 100, "weight")
    g2.add_tensor("a", 1000)
    g2.add_tensor("b", 10)
    g2.compute("f", inputs=("w",), outputs=("a",))
    g2.compute("g", inputs=("a",), outputs=("b",))
    # a dies after g (activation): auto-freed — same peak
    assert memsim.simulate(g2).peak_bytes == base
