"""Dry-run machinery smoke: a real (small-mesh) sharded lowering of
train/prefill/decode through the launch-layer sharding assignment, plus a
subprocess check that the production-mesh dry-run lowers one cheap combo.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, REGISTRY
from repro.data.pipeline import make_batch_specs
from repro.launch.mesh import make_debug_mesh
from repro.launch.shardings import batch_shardings, cache_shardings, param_shardings
from repro.models.model import build_model
from repro.sharding.rules import DEFAULT_RULES, axis_rules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_sharded_train_lowering_debug_mesh():
    """Full launch-layer path (param/batch shardings + jit lowering) on the
    1×1 debug mesh for a reduced config — no 512-device env needed."""
    cfg = REGISTRY["phi3-mini-3.8b"].reduced()
    model = build_model(cfg)
    mesh = make_debug_mesh((1, 1))
    rules = dict(DEFAULT_RULES)
    with axis_rules(rules, mesh), mesh:
        param_spec = model.param_specs(jnp.float32)
        p_shard = param_shardings(param_spec, mesh, rules)
        batch_spec = make_batch_specs(cfg, 32, 4, jnp.float32)
        b_shard = batch_shardings(batch_spec, mesh, rules)

        def fwd(params, batch):
            return model.loss(params, batch)

        lowered = jax.jit(fwd, in_shardings=(p_shard, b_shard)).lower(
            param_spec, batch_spec)
        compiled = lowered.compile()
        assert compiled.memory_analysis().temp_size_in_bytes >= 0


def test_sharded_decode_lowering_debug_mesh():
    cfg = REGISTRY["gemma2-9b"].reduced()
    model = build_model(cfg)
    mesh = make_debug_mesh((1, 1))
    rules = dict(DEFAULT_RULES)
    with axis_rules(rules, mesh), mesh:
        param_spec = model.param_specs(jnp.float32)
        p_shard = param_shardings(param_spec, mesh, rules)
        cache_spec = model.cache_specs(2, 64, jnp.float32)
        c_shard = cache_shardings(cache_spec, mesh, rules)
        tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def step(params, cache, token, p):
            return model.decode_step(params, cache, token, p)

        compiled = jax.jit(step, in_shardings=(
            p_shard, c_shard,
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        ), donate_argnums=(1,)).lower(param_spec, cache_spec, tok, pos).compile()
        assert compiled is not None


@pytest.mark.slow
def test_production_mesh_dryrun_subprocess():
    """One cheap production combo through the real dryrun CLI (512 fake
    devices in a subprocess so this process's device count is untouched)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k"],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=540, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
