"""Roofline HLO analyzer: loop-aware multipliers, collective byte parsing,
dot FLOP counting — on hand-written HLO snippets with known answers."""

import pytest

from repro.roofline.hlo_analysis import analyze, parse_module, _multipliers
from repro.roofline.analysis import collective_bytes, model_flops
from repro.configs import REGISTRY


SIMPLE_HLO = """\
HloModule test

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %w = f32[256,256]{1,0} constant({...})
  %dot.1 = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%dot.1), replica_groups={}
  ROOT %t = (s32[], f32[128,256]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(8)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %init = (s32[], f32[128,256]{1,0}) tuple(%a)
  %wl = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  %ag = f32[256,256]{1,0} all-gather(%a), replica_groups={}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%wl), index=1
}
"""


def test_loop_aware_flops_and_collectives():
    stats = analyze(SIMPLE_HLO)
    # dot: 2 * 128*256 * 256 flops, executed 8 times
    assert stats.flops == pytest.approx(8 * 2 * 128 * 256 * 256)
    # all-reduce inside the loop: 128*256*4 bytes × 8; all-gather outside: 256*256*4
    ar = 8 * 128 * 256 * 4
    ag = 256 * 256 * 4
    assert stats.coll_breakdown["all-reduce"] == pytest.approx(ar)
    assert stats.coll_breakdown["all-gather"] == pytest.approx(ag)
    assert stats.collective_bytes == pytest.approx(ar + ag)


def test_multipliers_nested():
    comps = parse_module(SIMPLE_HLO)
    mult = _multipliers(comps)
    assert mult["body"] == 8
    assert mult["main"] == 1


def test_collective_bytes_regex_variants():
    text = """
  %x.1 = bf16[16,512]{1,0} all-gather-start(%a), replica_groups={}
  %x.2 = bf16[16,512]{1,0} all-gather-done(%x.1)
  %y = f32[4]{0} collective-permute(%b), source_target_pairs={{0,1}}
"""
    coll = collective_bytes(text)
    assert coll["all-gather"] == 16 * 512 * 2
    assert coll["collective-permute"] == 4 * 4


def test_model_flops_moe_uses_active_params():
    dense = REGISTRY["phi3-mini-3.8b"]
    moe = REGISTRY["mixtral-8x22b"]
    assert model_flops(dense, 100) == pytest.approx(6 * dense.param_count() * 100)
    assert model_flops(moe, 100) < 6 * moe.param_count() * 100
    assert model_flops(moe, 100) == pytest.approx(6 * moe.active_param_count() * 100)


def test_param_counts_sane():
    """Analytic parameter counts should be near the advertised sizes."""
    expect = {
        "gemma2-9b": (8e9, 11e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "mixtral-8x22b": (130e9, 150e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "minicpm3-4b": (3.3e9, 5e9),
        "zamba2-7b": (6e9, 9e9),
        "whisper-medium": (0.6e9, 1.0e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
    }
    for name, (lo, hi) in expect.items():
        n = REGISTRY[name].param_count()
        assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"
