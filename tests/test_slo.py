"""SLO-aware scheduling: priority classes, deadline-driven preemption,
goodput-maximizing admission.

Policy units (no model): spec/config validation, candidate ordering,
outcome scoring, the preemption victim policy, and the bounded prefill
boost. Integration (reduced model): preempted-then-restored sequences are
token-identical to unpreempted runs (mid-decode and mid-prefill-chunk, in
resident and kv_offload mode), admission never over-commits pool capacity
with SLOs on, higher priority classes never starve lower ones to
incompleteness at 3x overload, and deadline-infeasible requests are shed
before admission rather than admitted and missed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import HyperOffloadSession, OffloadConfig
from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.offload.kvcache import worst_case_page_bytes
from repro.pool import DEVICE_TIER, HOST_TIER, TransferEngine, default_pool
from repro.sched import (
    DONE, PREFILL, SHED, ContinuousScheduler, Request, RequestState,
    SchedulerConfig, poisson_trace,
)
from repro.serving.engine import ServeEngine
from repro.slo import (
    DEFAULT_SLO, PRIORITY_CLASSES, GoodputController, PreemptionEngine,
    SLOConfig, SLOSpec, attainment_summary, candidate_key,
)

CFG = REGISTRY["phi3-mini-3.8b"].reduced()
MAX_SEQ = 32


@pytest.fixture(scope="module")
def model_and_params():
    m = build_model(CFG)
    return m, m.init(jax.random.key(0))


def _sequential_reference(model, params, requests):
    eng = ServeEngine(model, params, max_seq=MAX_SEQ)
    out = {}
    for r in requests:
        got = eng.generate({"tokens": jnp.asarray(r.tokens[None, :])},
                           r.max_new_tokens, seed=r.seed)
        out[r.req_id] = np.asarray(got)[0]
    eng.close()
    return out


def _state(slo=None, arrival=0.0, prompt=4, max_new=4, seed=0):
    return RequestState(request=Request(
        tokens=np.ones((prompt,), np.int32), max_new_tokens=max_new,
        arrival=arrival, seed=seed, slo=slo))


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------


def test_slospec_validation_and_rank():
    assert PRIORITY_CLASSES["interactive"] > PRIORITY_CLASSES["standard"] \
        > PRIORITY_CLASSES["batch"]
    assert SLOSpec("interactive", ttft_deadline=8.0).rank == 2
    assert DEFAULT_SLO.priority_class == "standard"
    assert DEFAULT_SLO.ttft_deadline is None
    with pytest.raises(ValueError, match="priority_class"):
        SLOSpec("urgent")
    with pytest.raises(ValueError, match="ttft_deadline"):
        SLOSpec("batch", ttft_deadline=0.0)
    with pytest.raises(ValueError, match="tpot_deadline"):
        SLOSpec("batch", tpot_deadline=-1.0)


def test_sloconfig_validation():
    with pytest.raises(ValueError, match="max_prefill_boost"):
        SLOConfig(max_prefill_boost=0.5)
    with pytest.raises(ValueError, match="max_preempt_per_step"):
        SLOConfig(max_preempt_per_step=-1)
    assert not SLOConfig().enable           # FIFO by default


def test_candidate_key_orders_class_deadline_fifo():
    batch = _state(SLOSpec("batch"), arrival=0.0)
    late_deadline = _state(SLOSpec("interactive", ttft_deadline=20.0),
                           arrival=1.0)
    tight_deadline = _state(SLOSpec("interactive", ttft_deadline=5.0),
                            arrival=2.0)
    unannotated = _state(None, arrival=0.5)     # standard, no deadlines
    order = sorted([batch, late_deadline, tight_deadline, unannotated],
                   key=candidate_key)
    assert order == [tight_deadline, late_deadline, unannotated, batch]
    # within a class with no deadlines, FIFO by (arrival, req_id)
    a, b = _state(arrival=3.0), _state(arrival=1.0)
    assert min([a, b], key=candidate_key) is b


def test_attainment_scores_and_shed_counts_as_miss():
    met = _state(SLOSpec("interactive", ttft_deadline=4.0), arrival=0.0,
                 max_new=3)
    met.status, met.out = DONE, [1, 2, 3]
    met.t_first_token, met.t_done = 3.0, 5.0
    missed = _state(SLOSpec("interactive", ttft_deadline=2.0), arrival=0.0,
                    max_new=2)
    missed.status, missed.out = DONE, [1, 2]
    missed.t_first_token, missed.t_done = 6.0, 7.0
    shed = _state(SLOSpec("interactive", ttft_deadline=2.0), arrival=0.0)
    shed.status, shed.t_done = SHED, 4.0
    free = _state(SLOSpec("batch"), max_new=2)       # no deadlines
    free.status, free.out = DONE, [1, 2]
    free.t_first_token, free.t_done = 50.0, 51.0

    att = attainment_summary([met, missed, shed, free])
    assert att["requests"] == 4 and att["shed"] == 1
    assert att["tokens"] == 7
    # goodput = met interactive (3) + deadline-free batch (2)
    assert att["met_tokens"] == 5
    ic = att["classes"]["interactive"]
    # shedding must not launder attainment: 1 met of 3 deadline-carriers
    assert ic["ttft_n"] == 3 and ic["ttft_met"] == 1
    assert ic["ttft_attainment"] == pytest.approx(1 / 3)
    bc = att["classes"]["batch"]
    assert bc["met_tokens"] == 2 and bc["ttft_attainment"] is None


def test_pick_victim_policy():
    eng = PreemptionEngine(SLOConfig(enable=True))
    eng.begin_step()
    remaining = lambda s: s.request.max_new_tokens - len(s.out)
    batch_long = _state(SLOSpec("batch"), max_new=10, seed=1)
    batch_short = _state(SLOSpec("batch"), max_new=5, seed=2)
    running = [batch_short, batch_long]
    urgent = _state(SLOSpec("interactive", ttft_deadline=2.0), arrival=4.0)

    # no TTFT deadline → pure-throughput work never preempts
    calm = _state(SLOSpec("interactive"), arrival=4.0)
    assert eng.pick_victim(calm, running, 4.0, est_prefill_steps=1.0,
                           remaining_steps=remaining) is None
    # slack covers the earliest natural retirement → patience suffices
    patient = _state(SLOSpec("interactive", ttft_deadline=20.0), arrival=4.0)
    assert eng.pick_victim(patient, running, 4.0, est_prefill_steps=1.0,
                           remaining_steps=remaining) is None
    # same class is never preempted (FIFO fairness within a class)
    peer = _state(SLOSpec("interactive", ttft_deadline=2.0), arrival=4.0)
    inter_running = [_state(SLOSpec("interactive", ttft_deadline=2.0),
                            max_new=10)]
    assert eng.pick_victim(peer, inter_running, 4.0, est_prefill_steps=1.0,
                           remaining_steps=remaining) is None
    # eligible: lowest class with the MOST remaining work is parked
    assert eng.pick_victim(urgent, running, 4.0, est_prefill_steps=1.0,
                           remaining_steps=remaining) is batch_long
    # per-step quota (default 1) now spent
    assert eng.pick_victim(urgent, running, 4.0, est_prefill_steps=1.0,
                           remaining_steps=remaining) is None
    eng.begin_step()   # next step: quota restored
    assert eng.pick_victim(urgent, running, 4.0, est_prefill_steps=1.0,
                           remaining_steps=remaining) is batch_long


def test_preemption_disabled_never_picks():
    eng = PreemptionEngine(SLOConfig(enable=True, preemption=False))
    eng.begin_step()
    urgent = _state(SLOSpec("interactive", ttft_deadline=1.0), arrival=0.0)
    running = [_state(SLOSpec("batch"), max_new=10)]
    assert eng.pick_victim(urgent, running, 5.0, est_prefill_steps=1.0,
                           remaining_steps=lambda s: 10) is None


def test_boost_budget_bounded():
    ctl = GoodputController(SLOConfig(enable=True, max_prefill_boost=3.0))
    # no deadline pressure → base budget, no boost counted
    calm = _state(SLOSpec("batch"), prompt=24)
    assert ctl.boost_budget(4, [calm], 0.0) == 4
    assert ctl.boosted_steps == 0
    # 24 tokens in 2 steps of slack needs 12/step — boosted
    pressed = _state(SLOSpec("interactive", ttft_deadline=2.0), prompt=24)
    assert ctl.boost_budget(4, [pressed], 0.0) == 12
    assert ctl.boosted_steps == 1
    # hopeless pressure is capped at ceil(base * max_prefill_boost)
    hopeless = _state(SLOSpec("interactive", ttft_deadline=1.0), prompt=28)
    hopeless.request.arrival = -30.0        # slack floor (max(slack,1)) hit
    assert ctl.boost_budget(4, [hopeless], 0.0) == 12   # == 4 * 3.0


def test_goodput_rate_floors_at_base_budget():
    ctl = GoodputController(SLOConfig(enable=True))
    assert ctl.rate(4) == 4.0               # no measurements yet
    ctl.note_step(16)
    assert ctl.rate(4) == 16.0              # EWMA seeds at first sample
    ctl.note_step(0)                        # idle steps don't decay it
    assert ctl.rate(4) == 16.0
    ctl.note_step(2)
    assert ctl.rate(4) >= 4.0               # never below the base budget


def test_infeasible_requires_deadline_and_flag():
    ctl = GoodputController(SLOConfig(enable=True))
    doomed = _state(SLOSpec("interactive", ttft_deadline=1.0), arrival=0.0)
    assert ctl.infeasible(doomed, 5.0, est_prefill_steps=1.0)
    assert not ctl.infeasible(doomed, 0.0, est_prefill_steps=1.0)
    assert not ctl.infeasible(_state(SLOSpec("batch")), 5.0,
                              est_prefill_steps=1.0)
    off = GoodputController(SLOConfig(enable=True, shed_infeasible=False))
    assert not off.infeasible(doomed, 5.0, est_prefill_steps=1.0)


# ---------------------------------------------------------------------------
# preempt/restore token identity
# ---------------------------------------------------------------------------


def _preempt_run(model, params, reqs, *, kv_offload=False, **cfg_kw):
    """Run on a 1-slot batch so the interactive arrival MUST preempt, then
    check every output against the unpreempted sequential reference."""
    pool = None
    if kv_offload:
        row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ,
                                                      jnp.float32))
        pool = default_pool(device_capacity=int(1.5 * row),
                            host_capacity=4 * row,
                            transfer=TransferEngine(depth=64))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, kv_offload=kv_offload,
                        slo=SLOConfig(enable=True), **cfg_kw),
        pool=pool)
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    assert sched.stats.preemptions >= 1 and sched.stats.resumes >= 1
    assert sched.stats.shed == 0
    victim = sched.finished[reqs[0].req_id]
    assert victim.status == DONE and victim.preemptions >= 1
    sched.close()
    if pool is not None:
        pool.close()
    return sched


@pytest.mark.parametrize("kv_offload", [False, True])
def test_preempt_mid_decode_token_identity(model_and_params, kv_offload):
    """A batch sequence parked mid-DECODE for an interactive arrival and
    later restored emits the exact token stream of an unpreempted run."""
    model, params = model_and_params
    rng = np.random.default_rng(10)
    reqs = [
        Request(tokens=rng.integers(0, CFG.vocab_size, 5, dtype=np.int32),
                max_new_tokens=10, arrival=0.0, seed=0,
                slo=SLOSpec("batch")),
        Request(tokens=rng.integers(0, CFG.vocab_size, 4, dtype=np.int32),
                max_new_tokens=3, arrival=3.0, seed=1,
                slo=SLOSpec("interactive", ttft_deadline=2.0)),
    ]
    sched = _preempt_run(model, params, reqs, kv_offload=kv_offload)
    ia = sched.finished[reqs[1].req_id]
    assert ia.t_first_token - reqs[1].arrival <= 2.0   # deadline held


@pytest.mark.parametrize("kv_offload", [False, True])
def test_preempt_mid_prefill_chunk_token_identity(model_and_params,
                                                  kv_offload):
    """A long prompt parked mid-prefill-CHUNK (partial row on chunk_cache /
    in the pool) resumes its chunk walk and stays token-identical."""
    model, params = model_and_params
    rng = np.random.default_rng(11)
    reqs = [
        Request(tokens=rng.integers(0, CFG.vocab_size, 24, dtype=np.int32),
                max_new_tokens=4, arrival=0.0, seed=0,
                slo=SLOSpec("batch")),
        Request(tokens=rng.integers(0, CFG.vocab_size, 4, dtype=np.int32),
                max_new_tokens=3, arrival=2.0, seed=1,
                slo=SLOSpec("interactive", ttft_deadline=6.0)),
    ]
    pool = None
    if kv_offload:
        row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ,
                                                      jnp.float32))
        pool = default_pool(device_capacity=int(1.5 * row),
                            host_capacity=4 * row,
                            transfer=TransferEngine(depth=64))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, chunk_size=4,
                        kv_offload=kv_offload, slo=SLOConfig(enable=True)),
        pool=pool)
    for r in reqs:
        sched.submit(r)
    # drive manually so the preemption moment is observable: the victim
    # must still be mid-prefill (no first token yet) when it is parked
    guard = 0
    while sched.stats.preemptions == 0:
        sched.step()
        guard += 1
        assert guard < 20, "expected a preemption within a few steps"
    victim = next(s for s in sched.preempted if s.req_id == reqs[0].req_id)
    assert victim.t_first_token is None          # parked mid-prefill…
    assert 0 < victim.prefill_pos < reqs[0].prompt_len   # …mid-chunk-walk
    out = sched.run()
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    assert sched.stats.resumes >= 1
    assert sched.finished[reqs[0].req_id].status == DONE
    sched.close()
    if pool is not None:
        pool.close()


# ---------------------------------------------------------------------------
# admission properties under SLO
# ---------------------------------------------------------------------------


def test_admission_never_overcommits_with_slo(model_and_params):
    """The over-commit invariant from test_sched holds verbatim with the
    SLO path on: preempted sequences keep their reservations, so device+
    host reserved bytes never exceed capacity and nothing spills remote."""
    model, params = model_and_params
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    for seed in range(3):
        reqs = poisson_trace(6, rate=5.0, vocab_size=CFG.vocab_size,
                             prompt_lens=(4, 8), new_tokens=(3, 8),
                             prompt_quantum=4, interactive_fraction=0.5,
                             seed=seed)
        pool = default_pool(device_capacity=row, host_capacity=row,
                            transfer=TransferEngine(depth=64))
        cap = 2 * row
        sched = ContinuousScheduler(
            model, params,
            SchedulerConfig(max_batch=3, max_seq=MAX_SEQ, kv_offload=True,
                            slo=SLOConfig(enable=True)),
            pool=pool)
        for r in reqs:
            sched.submit(r)
        guard = 0
        while len(sched.queue) or sched.active or sched.preempted:
            if not sched.active and not sched.preempted \
                    and sched.queue.head_ready(sched.now) is None:
                sched.now = sched.queue.next_arrival()
            sched.step()
            assert sched.pool.reserved_bytes((DEVICE_TIER, HOST_TIER)) <= cap
            snap = sched.pool.snapshot()
            assert snap["tier/remote"]["entries"] == 0, \
                "pages forced remote — SLO admission over-committed"
            guard += 1
            assert guard < 500
        # every request reached a terminal state (DONE or SHED) and every
        # reservation was released
        assert len(sched.finished) == len(reqs)
        assert sched.pool.reserved_bytes() == 0
        sched.close()
        pool.close()


def test_no_starvation_at_3x_overload(model_and_params):
    """Strict-priority admission at 3x overload must not starve the batch
    class: every batch request still runs to completion with its full
    decode budget (batch carries no deadline, so it can never be shed)."""
    model, params = model_and_params
    # ~3x the 2-slot service capacity for this mix
    reqs = poisson_trace(14, rate=1.2, vocab_size=CFG.vocab_size,
                         prompt_lens=(4, 8), new_tokens=(4, 8),
                         prompt_quantum=4, interactive_fraction=0.5,
                         seed=7)
    assert any((r.slo or DEFAULT_SLO).priority_class == "batch"
               for r in reqs)
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=4,
                        slo=SLOConfig(enable=True)))
    out = sched.run(reqs)
    assert len(sched.finished) == len(reqs)
    for r in reqs:
        st = sched.finished[r.req_id]
        if (r.slo or DEFAULT_SLO).priority_class == "batch":
            assert st.status == DONE
            assert len(out[r.req_id]) == r.max_new_tokens
    sched.close()


def test_infeasible_request_shed_before_admission(model_and_params):
    """A TTFT deadline no admission could meet — 24 prompt tokens at 4
    per step (boost disabled) cannot land a first token inside 3 steps —
    is shed at the queue: no slot, no prefill tokens, no output, and the
    attainment summary books it as a deadline miss, not a
    disappearance."""
    model, params = model_and_params
    doomed = Request(tokens=np.ones((24,), np.int32), max_new_tokens=4,
                     arrival=0.0, seed=0,
                     slo=SLOSpec("interactive", ttft_deadline=3.0))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, chunk_size=4,
                        slo=SLOConfig(enable=True, max_prefill_boost=1.0)))
    out = sched.run([doomed])
    st = sched.finished[doomed.req_id]
    assert st.status == SHED and st.t_done is not None
    assert out[doomed.req_id].size == 0
    assert sched.stats.shed == 1 and sched.stats.prefill_tokens == 0
    att = attainment_summary([st])
    assert att["shed"] == 1 and att["met_tokens"] == 0
    assert att["classes"]["interactive"]["ttft_attainment"] == 0.0
    sched.close()


def test_shed_disabled_admits_and_misses(model_and_params):
    """With shed_infeasible=False the same doomed request is admitted,
    served in full, and booked as a miss — tokens flow, goodput doesn't."""
    model, params = model_and_params
    doomed = Request(tokens=np.ones((24,), np.int32), max_new_tokens=4,
                     arrival=0.0, seed=0,
                     slo=SLOSpec("interactive", ttft_deadline=3.0))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, chunk_size=4,
                        slo=SLOConfig(enable=True, shed_infeasible=False,
                                      max_prefill_boost=1.0)))
    out = sched.run([doomed])
    assert sched.stats.shed == 0
    assert len(out[doomed.req_id]) == 4
    snap = sched.slo_snapshot()
    assert snap["missed_requests"] == 1 and snap["goodput_tokens"] == 0
    sched.close()


# ---------------------------------------------------------------------------
# config + session wiring
# ---------------------------------------------------------------------------


def test_offload_config_slo_round_trip_and_mode_gate():
    cfg = OffloadConfig(mode="continuous",
                        slo=SLOConfig(enable=True, max_prefill_boost=2.0,
                                      max_preempt_per_step=2))
    back = OffloadConfig.from_dict(cfg.to_dict())
    assert back.slo == cfg.slo and back.slo.max_preempt_per_step == 2
    assert OffloadConfig().slo == SLOConfig()      # default: disabled
    with pytest.raises(ValueError, match="slo.enable"):
        OffloadConfig(mode="resident", slo=SLOConfig(enable=True))
    with pytest.raises(ValueError, match="slo.enable"):
        OffloadConfig(mode="paged", slo=SLOConfig(enable=True))


def test_session_slo_stats_exposed(model_and_params):
    """The front door: session-built schedulers run the SLO policy and
    ``session.stats()['sched']`` carries the preemption/shed/goodput
    counters the launchers and benchmark report."""
    model, params = model_and_params
    rng = np.random.default_rng(12)
    reqs = [
        Request(tokens=rng.integers(0, CFG.vocab_size, 5, dtype=np.int32),
                max_new_tokens=10, arrival=0.0, seed=0,
                slo=SLOSpec("batch")),
        Request(tokens=rng.integers(0, CFG.vocab_size, 4, dtype=np.int32),
                max_new_tokens=3, arrival=3.0, seed=1,
                slo=SLOSpec("interactive", ttft_deadline=2.0)),
    ]
    session = HyperOffloadSession(OffloadConfig(
        mode="continuous", max_batch=1, max_seq=MAX_SEQ,
        slo=SLOConfig(enable=True)))
    sched = session.scheduler(model, params)
    sched.run(reqs)
    s = session.stats()["sched"]
    assert s["preemptions"] == 1 and s["resumes"] == 1 and s["shed"] == 0
    assert s["slo"]["goodput_tokens"] == 13      # both requests met
    assert s["slo"]["met_requests"] == 2
    assert s["slo"]["missed_requests"] == 0
    session.close()


def test_slo_disabled_keeps_fifo_counters_zero(model_and_params):
    """Without slo.enable the scheduler is byte-for-byte the FIFO path:
    no goodput controller, zero preemption/shed counters, no slo block in
    the session snapshot."""
    model, params = model_and_params
    session = HyperOffloadSession(OffloadConfig(
        mode="continuous", max_batch=1, max_seq=MAX_SEQ))
    sched = session.scheduler(model, params)
    sched.run([Request(tokens=np.ones((4,), np.int32), max_new_tokens=2,
                       slo=SLOSpec("interactive", ttft_deadline=1.0))])
    assert sched.slo_snapshot() is None
    s = session.stats()["sched"]
    assert s["preemptions"] == 0 and s["shed"] == 0
    assert "slo" not in s
    session.close()
