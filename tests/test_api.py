"""The `repro.api` front door: `OffloadConfig` serialization and surface
pinning, `HyperOffloadSession` single-pool wiring, the config-derived
transfer-depth policy, and the deprecation shims that keep the old
per-subsystem constructors working for one release."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api
from repro.api import HyperOffloadSession, OffloadConfig
from repro.api.__main__ import main as api_main
from repro.api.config import CalibrationConfig, PrefixCacheConfig
from repro.configs import REGISTRY
from repro.core.calibration import (
    CalibratedHardwareSpec, measurements_from_pairs,
)
from repro.core.costmodel import HardwareSpec
from repro.core.insertion import PAGED_INSERTION, InsertionOptions
from repro.core.schedule import ScheduleOptions
from repro.models.model import build_model
from repro.offload.kvcache import PagedKVCache
from repro.pool import TierSpec, TierTopology, auto_depth
from repro.sched import ContinuousScheduler, Request, SchedulerConfig
from repro.serving.engine import ServeEngine

CFG = REGISTRY["phi3-mini-3.8b"].reduced()
MAX_SEQ = 32


@pytest.fixture(scope="module")
def model_and_params():
    m = build_model(CFG)
    return m, m.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# public surface + config serialization
# ---------------------------------------------------------------------------


def test_public_api_surface_is_pinned():
    assert repro.api.__all__ == [
        "KVCodecConfig",
        "OffloadConfig",
        "HyperOffloadSession",
        "HW_SPECS",
        "MODES",
    ]


def test_config_round_trips_through_json():
    cfg = OffloadConfig(
        mode="kv_offload",
        hw="ascend_910c_like",
        device_capacity=1 << 20,
        host_capacity=1 << 22,
        transfer_depth=16,
        max_seq=64, max_batch=2, prefill_budget=2,
        chunk_size=16, prefill_tokens=32,
        cache_dtype="bfloat16",
        insertion=InsertionOptions(min_bytes=4096,
                                   force_prefixes=("kv_",)),
        schedule=ScheduleOptions(max_candidates=8),
        remat="offload", offload_opt_state=True)
    wire = json.loads(json.dumps(cfg.to_dict()))
    assert OffloadConfig.from_dict(wire) == cfg


def test_config_round_trips_custom_hardware():
    hw = HardwareSpec(name="lab_box", flops=1e12, hbm_bw=1e11,
                      hbm_bytes=8e9, pool_bw_d2r=1e10, pool_bw_r2d=1e10,
                      link_bw=1e10)
    cfg = OffloadConfig(hw=hw)
    wire = json.loads(json.dumps(cfg.to_dict()))
    back = OffloadConfig.from_dict(wire)
    assert back.hardware == hw
    # a registered spec serializes compactly, by name
    assert OffloadConfig(hw="tpu_v5e").to_dict()["hw"] == "tpu_v5e"


def test_config_validates_fields():
    with pytest.raises(ValueError, match="mode"):
        OffloadConfig(mode="turbo")
    with pytest.raises(ValueError, match="remat"):
        OffloadConfig(remat="sometimes")
    with pytest.raises(ValueError, match="hardware"):
        OffloadConfig(hw="abacus")
    with pytest.raises(ValueError, match="transfer_depth"):
        OffloadConfig(transfer_depth=0)
    with pytest.raises(ValueError, match="chunk_size"):
        OffloadConfig(chunk_size=0)
    with pytest.raises(ValueError, match="chunk_size"):
        OffloadConfig(chunk_size=256, max_seq=128)
    with pytest.raises(ValueError, match="requires chunk_size"):
        OffloadConfig(prefill_tokens=16)
    with pytest.raises(ValueError, match="unknown OffloadConfig fields"):
        OffloadConfig.from_dict({"modee": "resident"})
    # a typo inside a nested options dict must not silently default
    with pytest.raises(ValueError, match="unknown InsertionOptions fields"):
        OffloadConfig.from_dict({"insertion": {"min_byte": 4096}})


def test_mode_resolves_planner_and_depth_defaults():
    # offload modes plan every pool-resident KV tensor (the old hard-coded
    # min_bytes=1 at the PlanPrefetcher call site); resident keeps the
    # cost-model threshold
    assert OffloadConfig(mode="paged").insertion_options() == PAGED_INSERTION
    assert OffloadConfig(mode="kv_offload").insertion_options().min_bytes == 1
    assert OffloadConfig().insertion_options().min_bytes == 1 << 20
    custom = InsertionOptions(min_bytes=7)
    assert OffloadConfig(mode="paged",
                         insertion=custom).insertion_options() is custom
    # depth policy: auto derives from the consumer's shape, int pins
    auto = OffloadConfig()
    assert auto.depth_for(layers=16) == auto_depth(layers=16) == 64
    assert auto.depth_for(pages=40) == 80
    assert auto.depth_for() == 8                       # floor
    assert OffloadConfig(transfer_depth=3).depth_for(pages=1000) == 3


def test_kv_offload_override_keeps_mandatory_prefetch_planning(
        model_and_params):
    """session.scheduler(kv_offload=True) on a resident-mode session must
    still plan the mandatory prefetch of every pool-resident KV tensor —
    the resident cost-model thresholds would filter smoke-scale KV leaves
    out of the plan and the prefetcher would never issue a fetch."""
    model, params = model_and_params
    session = HyperOffloadSession(OffloadConfig(max_seq=32, max_batch=2))
    sched = session.scheduler(model, params, kv_offload=True)
    assert sched.cfg.insert_opts == PAGED_INSERTION
    assert sched.prefetcher is not None
    assert len(sched.prefetcher.planned_layers) > 0
    session.close()


def test_print_config_cli(capsys):
    assert api_main(["--print-config"]) == 0
    dumped = json.loads(capsys.readouterr().out)
    assert dumped["mode"] == "resident"
    assert dumped["transfer_depth"] == "auto"
    # the dump is the default config, exactly (drift detector for CI)
    resolved = dumped.pop("insertion_resolved")
    topo = dumped.pop("topology_resolved")
    assert OffloadConfig.from_dict(dumped) == OffloadConfig()
    assert resolved["min_bytes"] == OffloadConfig().insertion_options().min_bytes
    assert [t["name"] for t in topo["tiers"]] == ["device", "host", "remote"]


def test_config_topology_roundtrip_and_validation():
    topo = TierTopology(tiers=(
        TierSpec("device", kind="device", capacity=1 << 20),
        TierSpec("host", kind="host", capacity=1 << 22),
        TierSpec("cxl", kind="modeled", read_bw=5e9, write_bw=4e9,
                 read_latency_s=1e-4, admit=False),
    ))
    cfg = OffloadConfig(mode="kv_offload", topology=topo,
                        calibration=CalibrationConfig(min_transfers=4,
                                                      max_inflight=32))
    wire = json.loads(json.dumps(cfg.to_dict()))
    back = OffloadConfig.from_dict(wire)
    assert back == cfg and back.tier_topology == topo
    # no explicit topology: the default chain built from capacity fields
    d = OffloadConfig(host_capacity=1 << 20)
    assert d.tier_topology.names == ("device", "host", "remote")
    assert d.tier_topology.spec("host").capacity == 1 << 20
    with pytest.raises(ValueError, match="TierTopology"):
        OffloadConfig(topology={"tiers": []})        # dict, not the type
    with pytest.raises(ValueError, match="capacities"):
        OffloadConfig(topology=topo, host_capacity=1 << 20)
    with pytest.raises(ValueError, match="pin_tier"):
        OffloadConfig(mode="continuous", chunk_size=8, topology=topo,
                      prefix_cache=PrefixCacheConfig(enable=True,
                                                     pin_tier="remote"))
    # a disabled prefix cache never vetoes a custom chain (its default
    # pin names the legacy "host" tier)
    OffloadConfig(topology=TierTopology(tiers=(TierSpec("ram",
                                                        kind="numpy"),)))
    with pytest.raises(ValueError, match="min_transfers"):
        CalibrationConfig(min_transfers=0)
    with pytest.raises(ValueError, match="max_inflight"):
        CalibrationConfig(max_inflight=0)


# ---------------------------------------------------------------------------
# session wiring
# ---------------------------------------------------------------------------


def test_session_shares_one_pool_and_merges_stats(model_and_params):
    model, params = model_and_params
    cfg = OffloadConfig(mode="kv_offload", max_seq=MAX_SEQ, max_batch=2)
    with HyperOffloadSession(cfg) as session:
        engine = session.serve_engine(model, params)
        sched = session.scheduler(model, params)
        cache = session.paged_kv(batch=1, n_kv_heads=CFG.n_kv_heads,
                                 head_dim=CFG.head_dim)
        # exactly one pool / transfer engine behind every subsystem
        assert engine.pool is session.pool
        assert sched.pool is session.pool
        assert cache.pool is session.pool
        assert session.transfer is session.pool.transfer

        out = engine.generate(
            {"tokens": jnp.ones((1, 4), jnp.int32)}, 3)
        assert out.shape == (1, 3)
        sched.run([Request(tokens=np.ones((4,), np.int32),
                           max_new_tokens=4, seed=0)])

        s = session.stats()
        assert s["mode"] == "kv_offload"
        assert s["serve"]["engines"] == 1
        assert s["serve"]["decoded_tokens"] == 2      # 3 tokens, 2 decode steps
        assert s["serve"]["cache_round_trips"] == 2
        assert s["sched"]["schedulers"] == 1
        assert s["sched"]["retires"] == 1
        assert s["sched"]["prefetch"]["fetches_issued"] > 0
        assert s["paged"]["caches"] == 1
        assert s["pool"]["puts"] > 0 and "transfer" in s["pool"]
        assert s["plans_cached"] == 1
    # close() is idempotent and reaches the owned pool
    session.close()


def test_session_plan_cache_is_shared(model_and_params):
    model, params = model_and_params
    cfg = OffloadConfig(mode="kv_offload", max_seq=MAX_SEQ, max_batch=2)
    with HyperOffloadSession(cfg) as session:
        s1 = session.scheduler(model, params)
        s2 = session.scheduler(model, params)
        assert s1.prefetcher.plan is s2.prefetcher.plan   # one plan, reused
        assert session.stats()["plans_cached"] == 1


def test_session_auto_depth_grows_pinned_does_not(model_and_params):
    model, params = model_and_params
    with HyperOffloadSession(OffloadConfig(mode="kv_offload",
                                           max_seq=MAX_SEQ)) as session:
        base = session.transfer.depth
        session.paged_kv(batch=1, n_kv_heads=CFG.n_kv_heads,
                         head_dim=CFG.head_dim, max_seq=256, page_size=4)
        assert session.transfer.depth == max(base, 2 * (256 // 4))
    with HyperOffloadSession(OffloadConfig(mode="kv_offload",
                                           max_seq=MAX_SEQ,
                                           transfer_depth=5)) as session:
        session.paged_kv(batch=1, n_kv_heads=CFG.n_kv_heads,
                         head_dim=CFG.head_dim, max_seq=256, page_size=4)
        assert session.transfer.depth == 5                # pinned
    # the pin applies to an injected pool too
    from repro.pool import default_pool
    ext = default_pool(transfer_depth=5)
    session = HyperOffloadSession(
        OffloadConfig(mode="kv_offload", max_seq=MAX_SEQ, transfer_depth=5),
        pool=ext)
    session.paged_kv(batch=1, n_kv_heads=CFG.n_kv_heads,
                     head_dim=CFG.head_dim, max_seq=256, page_size=4)
    assert ext.transfer.depth == 5
    session.close()
    ext.close()


def test_session_scheduler_overrides(model_and_params):
    model, params = model_and_params
    cfg = OffloadConfig(mode="continuous", max_seq=MAX_SEQ, max_batch=4)
    with HyperOffloadSession(cfg) as session:
        sched = session.scheduler(model, params, max_batch=2,
                                  prefill_budget=2)
        assert sched.cfg.max_batch == 2
        assert sched.cfg.prefill_budget == 2
        assert sched.cfg.kv_offload is False              # continuous = resident
        with pytest.raises(TypeError, match="not both"):
            session.scheduler(model, params, SchedulerConfig(), max_batch=2)
        with pytest.raises(TypeError, match="not both"):
            session.train_step(model, session.train_config(), total_steps=5)
        with pytest.raises(TypeError, match="not both"):
            session.init_train_state(model, jax.random.key(0),
                                     ts=session.train_config(), total_steps=5)


def test_default_topology_is_behaviorally_identical(model_and_params):
    """ISSUE acceptance: an explicit `TierTopology.default()` serves
    token-identically to the legacy (topology=None) config in both
    resident and kv_offload modes, with the same stats() surface."""
    model, params = model_and_params
    batch = {"tokens": jnp.ones((2, 4), jnp.int32)}
    for mode in ("resident", "kv_offload"):
        outs, shapes = [], []
        for topo in (None, TierTopology.default()):
            cfg = OffloadConfig(mode=mode, max_batch=2, max_seq=MAX_SEQ,
                                topology=topo)
            with HyperOffloadSession(cfg) as s:
                out = s.serve_engine(model, params).generate(batch, 6)
                outs.append(np.asarray(out))
                st = s.stats()
                shapes.append((sorted(st), sorted(st["pool"])))
        np.testing.assert_array_equal(outs[0], outs[1])
        assert shapes[0] == shapes[1]


def test_recalibrate_replans_from_measured_bandwidth(model_and_params):
    """ISSUE acceptance: recalibrate() yields a spec whose transfer
    numbers are the byte-weighted measured per-tier-pair bandwidths (not
    the static HardwareSpec's), and swaps it into the planner and every
    live scheduler."""
    model, params = model_and_params
    cfg = OffloadConfig(mode="kv_offload", max_batch=2, max_seq=MAX_SEQ)
    with HyperOffloadSession(cfg) as s:
        sched = s.scheduler(model, params)
        sched.run([Request(tokens=np.ones((6,), np.int32),
                           max_new_tokens=4, seed=0)])
        # the serve engine's cache round trips produce the host->device
        # read traffic calibration feeds on
        s.serve_engine(model, params).generate(
            {"tokens": jnp.ones((2, 4), jnp.int32)}, 4)
        static = s.hw
        pairs = s.transfer.stats.snapshot()["pairs"]
        ms = measurements_from_pairs(pairs)
        spec = s.recalibrate()
        assert isinstance(spec, CalibratedHardwareSpec)
        assert spec.name == f"{static.name}+measured"
        # the scalar the cost model consumes is the measured byte-weighted
        # read bandwidth into the device tier, exactly
        reads = [m for (src, dst), m in ms.items()
                 if dst == "device" and src != "device"
                 and m.transfers >= 2 and m.nbytes >= 1024]
        assert reads, "serving must have produced eligible read traffic"
        expect = (sum(m.nbytes for m in reads)
                  / sum(m.busy_s for m in reads))
        assert spec.pool_bw_r2d == pytest.approx(expect)
        assert spec.pool_bw_r2d != static.pool_bw_r2d
        # the per-pair table carries each measured link
        for m in reads:
            assert spec.bandwidth_between(m.src, m.dst) == pytest.approx(
                m.bandwidth)
        # planner and scheduler both run on the measured spec now
        assert s.planner.hw is spec
        assert sched.cfg.hw is spec
        assert sched.prefetcher is not None
        # calibrating again never stacks name suffixes
        assert s.recalibrate().name == f"{static.name}+measured"


# ---------------------------------------------------------------------------
# implicit-private-pool construction is gone (was a one-release shim)
# ---------------------------------------------------------------------------


def test_offload_construction_requires_explicit_pool(model_and_params):
    model, params = model_and_params
    with pytest.raises(ValueError, match="HyperOffloadSession"):
        ServeEngine(model, params, max_seq=MAX_SEQ, offload_kv=True)
    with pytest.raises(ValueError, match="HyperOffloadSession"):
        ContinuousScheduler(
            model, params,
            SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True))
    with pytest.raises(ValueError, match="HyperOffloadSession"):
        PagedKVCache.create(batch=1, max_seq=64, page_size=16,
                            n_kv_heads=2, head_dim=8)


def test_session_construction_does_not_warn(model_and_params):
    """The front-door path raises no deprecation noise anywhere."""
    import warnings
    model, params = model_and_params
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with HyperOffloadSession(OffloadConfig(mode="kv_offload",
                                               max_seq=MAX_SEQ)) as session:
            session.serve_engine(model, params)
            session.scheduler(model, params)
            session.paged_kv(batch=1, n_kv_heads=CFG.n_kv_heads,
                             head_dim=CFG.head_dim)
