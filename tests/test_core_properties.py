"""Hypothesis property tests on HyperOffload's core invariants."""

import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed; property tests skipped")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import insertion, lifetime, memsim, schedule, timeline
from repro.core.allocator import FirstFitAllocator
from repro.core.costmodel import TPU_V5E
from repro.core.ir import Graph


@st.composite
def chain_graphs(draw):
    """Random layer chains with mixed tensor classes and sizes."""
    n = draw(st.integers(2, 8))
    g = Graph()
    g.add_tensor("x", draw(st.integers(1, 1 << 22)))
    prev = "x"
    skips = []
    for i in range(n):
        loc = draw(st.sampled_from(["device", "remote"]))
        g.add_tensor(f"w{i}", draw(st.integers(1, 1 << 28)), "weight", loc)
        g.add_tensor(f"h{i}", draw(st.integers(1, 1 << 24)))
        outs = [f"h{i}"]
        if draw(st.booleans()):
            g.add_tensor(f"s{i}", draw(st.integers(1 << 20, 1 << 28)))
            outs.append(f"s{i}")
            skips.append(f"s{i}")
        g.compute(f"f{i}", inputs=(prev, f"w{i}"), outputs=tuple(outs),
                  flops=draw(st.floats(1e9, 1e13)), hbm_bytes=1e6)
        prev = f"h{i}"
    if skips:
        g.add_tensor("y", 8)
        g.compute("tail", inputs=(prev, *skips), outputs=("y",), flops=1e10)
    return g


@given(chain_graphs())
@settings(max_examples=40, deadline=None)
def test_insertion_produces_valid_graph(g):
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    g2.validate_order(g2.order())
    # every compute node survives, exactly once
    comp0 = [n for n, v in g.nodes.items() if v.kind == "compute"]
    comp1 = [n for n, v in g2.nodes.items() if v.kind == "compute"]
    assert comp0 == comp1


@given(chain_graphs())
@settings(max_examples=25, deadline=None)
def test_refined_order_invariants(g):
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    order = schedule.refine_order(g2, TPU_V5E)
    # permutation + validity
    assert sorted(order) == sorted(g2.order())
    g2.validate_order(order)
    # every prefetch precedes its tensor's next compute consumer
    pos = {n: i for i, n in enumerate(order)}
    for n, node in g2.nodes.items():
        if node.kind != "prefetch":
            continue
        consumers = [pos[c] for c, cn in g2.nodes.items()
                     if cn.kind == "compute" and node.tensor in cn.inputs
                     and pos[c] > pos[n]]
        # at least the consumer it was inserted for is still after it,
        # unless the tensor has no consumer after the offload gap
        reads_after_any = [pos[c] for c, cn in g2.nodes.items()
                           if cn.kind == "compute" and node.tensor in cn.inputs]
        if reads_after_any and max(reads_after_any) > pos[n]:
            assert consumers, f"prefetch {n} scheduled after all consumers"


@given(chain_graphs())
@settings(max_examples=25, deadline=None)
def test_offload_never_increases_peak(g):
    base_peak = memsim.simulate(g.residentize()).peak_bytes
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    order = schedule.refine_order(g2, TPU_V5E)
    opt_peak = memsim.simulate(g2, order).peak_bytes
    assert opt_peak <= base_peak


@given(st.lists(st.tuples(st.sampled_from(["a", "f"]),
                          st.integers(0, 9),
                          st.integers(1, 1 << 16)),
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_allocator_invariants(ops):
    a = FirstFitAllocator(1 << 20, alignment=64)
    live = {}
    for kind, tid, size in ops:
        name = f"t{tid}"
        if kind == "a" and name not in live:
            if a.alloc(name, size):
                live[name] = size
        elif kind == "f" and name in live:
            a.free(name)
            live.pop(name)
        # no overlap between blocks
        blocks = sorted(a.blocks.values())
        for (o1, s1), (o2, s2) in zip(blocks, blocks[1:]):
            assert o1 + s1 <= o2
        # all blocks within capacity
        assert all(o + s <= a.capacity for o, s in a.blocks.values())


@given(chain_graphs(), st.floats(10e9, 200e9))
@settings(max_examples=20, deadline=None)
def test_timeline_total_bounds(g, bw):
    hw = TPU_V5E.with_pool_bw(bw)
    g2 = insertion.insert_cache_ops(g, hw)
    tl = timeline.simulate(g2, hw)
    # total ≥ compute-only lower bound; exposed = total - busy
    assert tl.total >= tl.compute_busy - 1e-12
    assert abs(tl.exposed_comm - (tl.total - tl.compute_busy)) < 1e-9
