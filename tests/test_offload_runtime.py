"""JAX-native offload runtime: remat policies, optimizer-state offload,
paged KV cache, serving engine round trips — all must be numerically
equivalent to the resident baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model
from repro.offload.kvcache import PagedKVCache
from repro.offload.optstate import device_fetch_state, host_offload_state
from repro.pool import default_pool
from repro.pool.backend import is_host_resident
from repro.kernels.ref import decode_attention_ref
from repro.serving.engine import ServeEngine
from repro.training.step import TrainStepConfig, init_train_state, make_train_step


CFG = REGISTRY["phi3-mini-3.8b"].reduced()


def _train(remat, offload_opt, steps=8):
    m = build_model(CFG)
    ts = TrainStepConfig(remat=remat, offload_opt_state=offload_opt,
                         warmup=2, total_steps=steps, peak_lr=1e-3)
    params, opt = init_train_state(m, jax.random.key(0), ts=ts)
    step = make_train_step(m, ts)
    data = SyntheticTokens(CFG.vocab_size, seq_len=24, global_batch=4, noise=0.05)
    for i in range(steps):
        params, opt, metrics = step(params, opt, data.batch(i))
    return params, opt, float(metrics["loss"])


def test_offload_training_bitwise_matches_resident():
    p_res, _, l_res = _train("none", False)
    p_off, opt_off, l_off = _train("offload", True)
    assert l_res == pytest.approx(l_off, abs=1e-6)
    for a, b in zip(jax.tree.leaves(p_res), jax.tree.leaves(p_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # moments really live in host memory (probed kind; NumPy as last resort)
    assert all(is_host_resident(x) for x in jax.tree.leaves(opt_off.mu))


def test_full_remat_matches_no_remat():
    p1, _, l1 = _train("none", False)
    p2, _, l2 = _train("full", False)
    assert l1 == pytest.approx(l2, rel=1e-5)


def test_host_offload_round_trip_preserves_values():
    tree = {"a": jnp.arange(128.0).reshape(8, 16),
            "b": jnp.ones((4,), jnp.bfloat16)}
    parked = host_offload_state(tree)
    assert all(is_host_resident(x) for x in jax.tree.leaves(parked))
    back = device_fetch_state(parked)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_offload_kv_equals_resident():
    m = build_model(CFG)
    params = m.init(jax.random.key(0))
    data = SyntheticTokens(CFG.vocab_size, seq_len=16, global_batch=4)
    prompt = {"tokens": data.batch(0)["tokens"]}
    res = ServeEngine(m, params, max_seq=32).generate(prompt, 8)
    pool = default_pool()
    off_engine = ServeEngine(m, params, max_seq=32, offload_kv=True,
                             pool=pool)
    off = off_engine.generate(prompt, 8)
    np.testing.assert_array_equal(np.asarray(res), np.asarray(off))
    assert off_engine.stats.cache_round_trips == 7
    # real traffic went through the pool manager
    pool = off_engine.pool_stats()
    assert pool["puts"] > 0 and pool["bytes_stored"] > 0
    assert pool["gets"] > 0 and pool["bytes_fetched"] > 0
    assert pool["transfer"]["issued"] > 0


# ---------------------------------------------------------------------------
# Paged KV cache
# ---------------------------------------------------------------------------


def test_paged_kvcache_all_pages_exact():
    """Selecting all pages must reproduce dense ring attention exactly."""
    b, hq, hkv, d, page = 2, 4, 2, 32, 8
    max_seq = 64
    cache = PagedKVCache.create(batch=b, max_seq=max_seq, page_size=page,
                                n_kv_heads=hkv, head_dim=d,
                                pool=default_pool())
    ks = jax.random.split(jax.random.key(0), 3)
    s0 = 29   # 3 full pages + tail of 5
    k_seq = jax.random.normal(ks[0], (b, s0, hkv, d))
    v_seq = jax.random.normal(ks[1], (b, s0, hkv, d))
    cache.prefill(k_seq, v_seq)
    assert cache.full_pages == 3 and cache.tail_len == 5

    q = jax.random.normal(ks[2], (b, hq, d))
    out = cache.attend(q, scale=d ** -0.5, top_k_pages=None)
    # dense oracle over a big ring buffer holding the same tokens
    kd = jnp.zeros((b, hkv, max_seq, d)).at[:, :, :s0].set(
        k_seq.transpose(0, 2, 1, 3))
    vd = jnp.zeros((b, hkv, max_seq, d)).at[:, :, :s0].set(
        v_seq.transpose(0, 2, 1, 3))
    ref = decode_attention_ref(q, kd, vd, jnp.int32(s0 - 1), scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    assert cache.flushes == 3


def test_paged_kvcache_append_flush_and_sparse_selection():
    b, hq, hkv, d, page = 1, 2, 1, 16, 4
    cache = PagedKVCache.create(batch=b, max_seq=32, page_size=page,
                                n_kv_heads=hkv, head_dim=d,
                                pool=default_pool())
    ks = jax.random.split(jax.random.key(1), 64)
    for t in range(10):
        cache.append(jax.random.normal(ks[2 * t], (b, hkv, d)),
                     jax.random.normal(ks[2 * t + 1], (b, hkv, d)))
    assert cache.length == 10 and cache.full_pages == 2 and cache.tail_len == 2
    q = jax.random.normal(ks[-1], (b, hq, d))
    idx = cache.select_pages(q, top_k=1)
    assert len(idx) == 1 and 0 <= idx[0] < 2
    out = cache.attend(q, scale=d ** -0.5, top_k_pages=1)
    assert out.shape == (b, hq, d)
    assert not bool(jnp.isnan(out).any())
    assert cache.fetches >= 1
    # pool pages really live in the manager's host tier
    assert any(k is not None for k in cache.k_pool)
    assert all(cache.pool.tier_of(k) == "host" and cache.pool.is_host_resident(k)
               for k in cache.k_pool if k is not None)
    stats = cache.pool_stats()
    assert stats["bytes_stored"] > 0 and stats["bytes_fetched"] > 0


def _filled_cache(codec=None, device_pages=None, seed=0,
                  b=2, hq=4, hkv=2, d=32, page=8, s0=29):
    pool = default_pool(codec=codec, codec_below="host") if codec \
        else default_pool()
    cache = PagedKVCache.create(batch=b, max_seq=64, page_size=page,
                                n_kv_heads=hkv, head_dim=d, pool=pool,
                                device_pages=device_pages)
    ks = jax.random.split(jax.random.key(seed), 3)
    cache.prefill(jax.random.normal(ks[0], (b, s0, hkv, d)),
                  jax.random.normal(ks[1], (b, s0, hkv, d)))
    q = jax.random.normal(ks[2], (b, hq, d))
    return cache, q, d ** -0.5


def test_attend_fused_bitwise_matches_gather_and_caches_pages():
    """The fused decode path must be token-identical to the legacy
    gather/concat path (same decoded pages, same math), and the device
    page buffer must turn repeat visits into hits, not pool fetches."""
    cache, q, scale = _filled_cache()
    gather = cache.attend(q, scale=scale, top_k_pages=None)
    fused = cache.attend_fused(q, scale=scale)
    assert bool(jnp.all(fused == gather))
    assert cache.buffer_misses == 3 and cache.buffer_hits == 0
    fetches0 = cache.fetches
    again = cache.attend_fused(q, scale=scale)
    assert bool(jnp.all(again == gather))
    assert cache.buffer_hits == 3 and cache.fetches == fetches0
    # Pallas kernel variant: same pages, online-softmax numerics
    out = cache.attend_fused(q, scale=scale, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gather),
                               atol=2e-5)


def test_attend_fused_restricted_budget_evicts_lru():
    """A device_pages budget below the page count still serves sparse
    selections (mixed pool/device residency) but refuses a selection
    wider than the buffer instead of silently truncating it."""
    cache, q, scale = _filled_cache(device_pages=2, seed=1)
    top2 = cache.attend_fused(q, scale=scale, top_k_pages=2)
    ref = cache.attend(q, scale=scale, top_k_pages=2)
    assert bool(jnp.all(top2 == ref))
    with pytest.raises(ValueError, match="smaller than one step's"):
        cache.attend_fused(q, scale=scale)   # 3 pages > 2 slots


def test_attend_fused_int8_codec_matches_gather_and_bounds_error():
    """Under an int8 pool codec both paths decode the same quantized
    pages — fused stays bitwise-identical to gather — and the result
    stays close to a full-precision run of the same tokens."""
    cache, q, scale = _filled_cache(codec="int8", seed=2)
    exact, q2, _ = _filled_cache(codec=None, seed=2)
    gather = cache.attend(q, scale=scale, top_k_pages=None)
    fused = cache.attend_fused(q, scale=scale)
    assert bool(jnp.all(fused == gather))
    oracle = exact.attend(q2, scale=scale, top_k_pages=None)
    assert float(jnp.max(jnp.abs(fused - oracle))) < 0.05
