"""Attention unit tests (ring buffers, windows, softcap, M-RoPE) and MoE
dispatch properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.configs import REGISTRY
from repro.configs.base import LayerSpec, MoEConfig, ModelConfig, Segment
from repro.models import attention as A
from repro.models import moe as M
from repro.models.common import apply_rope


# ---------------------------------------------------------------------------
# Ring buffer
# ---------------------------------------------------------------------------


def test_ring_valid_mask_prefix():
    m = A._ring_valid_mask(jnp.int32(3), 8)
    np.testing.assert_array_equal(np.asarray(m),
                                  [True] * 4 + [False] * 4)


def test_ring_valid_mask_wrapped():
    # pos=9, C=8: all slots live
    m = A._ring_valid_mask(jnp.int32(9), 8)
    assert bool(jnp.all(m))


@given(st.integers(0, 50), st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_ring_mask_matches_bruteforce(pos, c):
    m = np.asarray(A._ring_valid_mask(jnp.int32(pos), c))
    expect = np.zeros(c, bool)
    for t in range(max(0, pos - c + 1), pos + 1):
        expect[t % c] = True
    np.testing.assert_array_equal(m, expect)


def test_ring_write_seq_wraps_correctly():
    buf = jnp.zeros((1, 4, 1, 1))
    vals = jnp.arange(10.0).reshape(1, 10, 1, 1)
    out = A._ring_write_seq(buf, vals)
    # token t at slot t % 4: tokens 6..9 survive
    got = np.asarray(out[0, :, 0, 0])
    np.testing.assert_array_equal(got, [8, 9, 6, 7])


def test_sliding_window_decode_equals_full_with_window_mask():
    """A windowed layer's ring cache must reproduce full attention restricted
    to the window."""
    cfg = REGISTRY["gemma2-9b"].reduced()
    spec_w = LayerSpec(mixer="attn", ffn="swiglu", window=6)
    p = A.init_attn_params(cfg, spec_w, jax.random.key(0), jnp.float32)
    b, s = 1, 16
    x = 0.3 * jax.random.normal(jax.random.key(1), (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = A.attention_full(cfg, spec_w, p, x, pos)  # masked full attention
    cache = A.init_attn_cache(cfg, spec_w, b, s, jnp.float32)
    assert cache["k"].shape[1] == 6  # ring capacity = window
    _, cache = A.attention_prefill(cfg, spec_w, p, x[:, : s - 1], pos[:, : s - 1], cache)
    out, _ = A.attention_decode(cfg, spec_w, p, x[:, s - 1 :], jnp.int32(s - 1),
                                pos[:, s - 1 :], cache)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_mrope_sections_differ_from_plain_rope():
    x = jax.random.normal(jax.random.key(0), (1, 4, 2, 16))
    pos2d = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    pos3d = jnp.stack([pos2d, pos2d * 2, pos2d * 3])  # distinct planes
    plain = apply_rope(x, pos2d, 10000.0)
    mr = apply_rope(x, pos3d, 10000.0, mrope_sections=(2, 3, 3))
    assert not np.allclose(np.asarray(plain), np.asarray(mr))
    # equal planes reduce to plain rope
    mr_eq = apply_rope(x, jnp.stack([pos2d] * 3), 10000.0,
                       mrope_sections=(2, 3, 3))
    np.testing.assert_allclose(np.asarray(plain), np.asarray(mr_eq), atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------


def moe_cfg(e=4, k=2, cf=2.0):
    return ModelConfig(
        name="t", family="moe", citation="x", d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
        segments=(Segment(pattern=(LayerSpec(mixer="attn", ffn="moe"),), repeats=1),),
        moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=32, capacity_factor=cf),
    )


def test_moe_lossless_capacity_weight_sum():
    """With capacity ≥ N no tokens drop: output = weighted expert mix, and
    permutation of tokens permutes outputs (no cross-token leakage)."""
    cfg = moe_cfg(cf=4.0)
    p = M.init_moe_params(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    out, aux = M.moe_ffn(cfg, p, x)
    assert out.shape == x.shape and float(aux) > 0
    perm = jnp.array([3, 1, 0, 2, 7, 5, 6, 4])
    out_p, _ = M.moe_ffn(cfg, p, x[:, perm])
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out[:, perm]),
                               atol=1e-5)


def test_moe_capacity_drops_some_tokens():
    cfg = moe_cfg(cf=0.3)
    p = M.init_moe_params(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32))
    out, _ = M.moe_ffn(cfg, p, x)
    # dropped tokens produce exactly zero output rows
    norms = jnp.linalg.norm(out.reshape(-1, 32), axis=-1)
    assert bool(jnp.any(norms == 0.0))
    assert bool(jnp.any(norms > 0.0))


def test_moe_grads_flow_to_all_param_groups():
    cfg = moe_cfg(cf=4.0)
    p = M.init_moe_params(cfg, jax.random.key(0), jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 32))

    def loss(p):
        out, aux = M.moe_ffn(cfg, p, x)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    for name, leaf in g.items():
        assert float(jnp.max(jnp.abs(leaf))) > 0, f"zero grad for {name}"
