"""Algorithm 1 (execution-order refinement), insertion, timeline, planner."""

import pytest

from repro.core import insertion, memsim, schedule, timeline
from repro.core.costmodel import TPU_V5E, ASCEND_LIKE
from repro.core.ir import Graph
from repro.core.planner import HyperOffloadPlanner

from conftest import small_graph


def chain_with_remote_weights(n=6, wbytes=256 << 20, flops=2e12):
    g = Graph()
    g.add_tensor("x", 1 << 20)
    prev = "x"
    for i in range(n):
        g.add_tensor(f"w{i}", wbytes, "weight", "remote")
        g.add_tensor(f"h{i}", 1 << 20)
        g.compute(f"f{i}", inputs=(prev, f"w{i}"), outputs=(f"h{i}",),
                  flops=flops, hbm_bytes=1e6)
        prev = f"h{i}"
    return g


def test_insertion_adds_mandatory_prefetches():
    g = chain_with_remote_weights()
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    prefetches = [n for n in g2.order() if g2.nodes[n].kind == "prefetch"]
    assert len(prefetches) == 6
    g2.validate_order(g2.order())


def test_insertion_respects_min_bytes():
    g = chain_with_remote_weights(wbytes=1024)  # below min_bytes
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    # tiny tensors are not offloaded; remote-initial flag flipped to device
    assert all(not n.is_cache_op for n in g2.nodes.values())
    assert g2.tensors["w0"].initial_location == "device"


def test_insertion_rejects_unamortizable_activation():
    g = small_graph()
    # make compute so fast nothing amortizes
    for node in g.nodes.values():
        node.flops = 1.0
    g2 = insertion.insert_cache_ops(
        g, TPU_V5E, insertion.InsertionOptions(offload_states=False))
    stores = [n for n in g2.nodes.values() if n.kind == "store"]
    assert not stores


def test_refined_order_is_valid_and_not_worse():
    g = chain_with_remote_weights()
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    naive = g2.order()
    refined = schedule.refine_order(g2, TPU_V5E, naive)
    g2.validate_order(refined)
    assert sorted(refined) == sorted(naive)
    tl_n = timeline.simulate(g2, TPU_V5E, naive)
    tl_r = timeline.simulate(g2, TPU_V5E, refined)
    mem_n = memsim.simulate(g2, naive).peak_bytes
    mem_r = memsim.simulate(g2, refined).peak_bytes
    # Algorithm 1's combined objective must not get worse
    lam = schedule.ScheduleOptions().mem_weight
    cost_n = tl_n.exposed_comm + lam * (mem_n / TPU_V5E.hbm_bytes) * tl_n.total
    cost_r = tl_r.exposed_comm + lam * (mem_r / TPU_V5E.hbm_bytes) * tl_r.total
    assert cost_r <= cost_n + 1e-9


def test_refinement_fixes_adversarial_early_prefetch():
    """All prefetches hoisted to the front (Fig. 4b: maximal residency) —
    Algorithm 1 must push them toward just-in-time positions."""
    g = chain_with_remote_weights()
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    # adversarial order: all prefetches first
    pre = [n for n in g2.order() if g2.nodes[n].kind == "prefetch"]
    rest = [n for n in g2.order() if g2.nodes[n].kind != "prefetch"]
    adversarial = pre + rest
    g2.validate_order(adversarial)
    peak_adv = memsim.simulate(g2, adversarial).peak_bytes
    refined = schedule.refine_order(g2, TPU_V5E, adversarial)
    peak_ref = memsim.simulate(g2, refined).peak_bytes
    assert peak_ref < peak_adv  # residency waste removed
    # overlap preserved: exposed only the first transfer
    tl = timeline.simulate(g2, TPU_V5E, refined)
    first = TPU_V5E.transfer_time(g2.tensors["w0"].nbytes, "r2d")
    assert tl.exposed_comm == pytest.approx(first, rel=0.2)


def test_timeline_overlap_vs_serial():
    g = chain_with_remote_weights()
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    tl = timeline.simulate(g2, TPU_V5E)
    compute_total = tl.compute_busy
    # transfers (beyond the first) hide behind compute
    assert tl.total < compute_total + 6 * TPU_V5E.transfer_time(256 << 20, "r2d")


def test_reactive_baseline_slower_than_planned():
    g = chain_with_remote_weights()
    base = g.residentize()
    cap = 3 * (256 << 20)  # fits 3 weights
    tl_reactive = timeline.simulate_reactive(base, TPU_V5E, cap)
    g2 = insertion.insert_cache_ops(g, TPU_V5E)
    tl_plan = timeline.simulate(g2, TPU_V5E,
                                schedule.refine_order(g2, TPU_V5E))
    assert tl_reactive.stalls > 0
    assert tl_plan.total < tl_reactive.total


def test_planner_end_to_end_summary():
    g = chain_with_remote_weights()
    plan = HyperOffloadPlanner(TPU_V5E, reactive_capacity=3 * (256 << 20)).plan(g)
    s = plan.summary()
    assert s["opt_peak_gb"] < s["base_peak_gb"]
    assert plan.reactive_timeline.total > plan.timeline.total
    assert plan.peak_reduction > 0.5


def test_bandwidth_sweep_monotonic():
    """More pool bandwidth ⇒ never slower (Fig. 6 trend)."""
    g = chain_with_remote_weights()
    totals = []
    for bw in (20e9, 40e9, 80e9, 160e9):
        hw = TPU_V5E.with_pool_bw(bw)
        g2 = insertion.insert_cache_ops(g, hw)
        tl = timeline.simulate(g2, hw)
        totals.append(tl.total)
    assert totals == sorted(totals, reverse=True)
