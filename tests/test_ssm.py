"""Mamba2 SSD: chunked algorithm vs O(S) recurrence, prefill/decode chain."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import ssm
from repro.models.ssm import ssd_chunked


def recurrent_reference(x, a, b_mat, c_mat):
    """Literal per-token SSM recurrence in f64-ish f32."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        da = jnp.exp(a[:, t])                                  # (B,H)
        state = state * da[..., None, None] + jnp.einsum(
            "bhn,bhp->bhpn", b_mat[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", c_mat[:, t], state))
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_recurrence(chunk):
    bsz, s, h, p, n = 2, 64, 2, 8, 4
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    a = -jnp.abs(jax.random.normal(ks[1], (bsz, s, h))) * 0.2
    bm = jax.random.normal(ks[2], (bsz, s, h, n)) * 0.5
    cm = jax.random.normal(ks[3], (bsz, s, h, n)) * 0.5
    y_c, st_c = ssd_chunked(x, a, bm, cm, chunk)
    y_r, st_r = recurrent_reference(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_r), atol=1e-4)


def test_mamba_prefill_then_decode_matches_forward():
    """prefill(s-1) + decode(1) must equal the full-sequence block output."""
    cfg = REGISTRY["mamba2-370m"].reduced()
    p = ssm.init_mamba_params(cfg, jax.random.key(0), jnp.float32)
    bsz, s = 2, 20
    x = 0.5 * jax.random.normal(jax.random.key(1), (bsz, s, cfg.d_model))
    full = ssm.mamba_forward(cfg, p, x)
    cache = ssm.init_mamba_cache(cfg, bsz, jnp.float32)
    out_pre, cache = ssm.mamba_prefill(cfg, p, x[:, : s - 1], cache)
    np.testing.assert_allclose(np.asarray(out_pre), np.asarray(full[:, : s - 1]),
                               atol=2e-4)
    out_dec, cache = ssm.mamba_decode(cfg, p, x[:, s - 1 : s], cache)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]), np.asarray(full[:, s - 1]),
                               atol=2e-4)


def test_mamba_decode_chain_long():
    """Many sequential decode steps track the full-sequence output."""
    cfg = REGISTRY["mamba2-370m"].reduced()
    p = ssm.init_mamba_params(cfg, jax.random.key(0), jnp.float32)
    bsz, s = 1, 33
    x = 0.5 * jax.random.normal(jax.random.key(1), (bsz, s, cfg.d_model))
    full = ssm.mamba_forward(cfg, p, x)
    cache = ssm.init_mamba_cache(cfg, bsz, jnp.float32)
    outs = []
    for t in range(s):
        o, cache = ssm.mamba_decode(cfg, p, x[:, t : t + 1], cache)
        outs.append(o[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-4)
