"""Cross-request prefix cache (`repro.prefix`): radix index semantics,
ref-counted/pinned pages vs pool eviction, tier-floor invalidation through
the evict listener, and end-to-end scheduler integration — prefix-hit
serving must stay token-identical to cold serving in both resident and
kv_offload modes while skipping the shared prompt tokens' prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import HyperOffloadSession, OffloadConfig
from repro.api.config import PrefixCacheConfig
from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.offload.kvcache import worst_case_page_bytes
from repro.pool import (
    DEVICE_TIER, HOST_TIER, MemoryPoolManager, TierState, TransferEngine,
    default_pool,
)
from repro.pool import backend as B
from repro.prefix import PrefixCacheManager, RadixPrefixIndex
from repro.sched import (
    ContinuousScheduler, Request, SchedulerConfig, poisson_trace,
)
from repro.serving.engine import ServeEngine

CFG = REGISTRY["phi3-mini-3.8b"].reduced()
MAX_SEQ = 32


@pytest.fixture(scope="module")
def model_and_params():
    m = build_model(CFG)
    return m, m.init(jax.random.key(0))


def _toks(*ids):
    return np.asarray(ids, np.int32)


# ---------------------------------------------------------------------------
# radix index
# ---------------------------------------------------------------------------


def test_radix_match_insert_remove():
    idx = RadixPrefixIndex(page_size=2)
    assert idx.match(_toks(1, 2, 3, 4)) == []

    chain, created = idx.insert(_toks(1, 2, 3, 4), 2)
    assert len(chain) == 2 and created == chain and len(idx) == 2
    assert [n.depth for n in chain] == [1, 2]

    # longest-prefix semantics at page granularity
    assert len(idx.match(_toks(1, 2, 3, 4, 9, 9))) == 2
    assert len(idx.match(_toks(1, 2, 9, 9))) == 1       # diverges at page 2
    assert idx.match(_toks(9, 9, 3, 4)) == []           # diverges at page 1
    assert len(idx.match(_toks(1, 2, 3))) == 1          # partial page ignored
    assert len(idx.match(_toks(1, 2, 3, 4), max_pages=1)) == 1

    # re-insert is idempotent; extending shares the existing chain
    chain2, created2 = idx.insert(_toks(1, 2, 3, 4, 5, 6), 3)
    assert created2 == chain2[2:] and chain2[:2] == chain
    assert len(idx) == 3

    # removing an interior node prunes the whole subtree
    removed = idx.remove(chain[1])
    assert {n.node_id for n in removed} == {n.node_id for n in chain2[1:]}
    assert len(idx) == 1 and len(idx.match(_toks(1, 2, 3, 4))) == 1

    with pytest.raises(ValueError):
        idx.insert(_toks(1, 2, 3), 2)    # no 2 full pages in 3 tokens


def test_radix_evictable_is_coldest_unrefd_leaves():
    idx = RadixPrefixIndex(page_size=1)
    a, _ = idx.insert(_toks(1, 2), 2)         # chain 1 -> 2
    b, _ = idx.insert(_toks(1, 7), 2)         # shares the root page
    idx.match(_toks(1, 2))                    # chain a is now hotter
    ev = idx.evictable()
    # only leaves qualify (the shared interior page would orphan both)
    assert [n.node_id for n in ev] == [b[1].node_id, a[1].node_id]
    b[1].refs = 1
    assert [n.node_id for n in idx.evictable()] == [a[1].node_id]


# ---------------------------------------------------------------------------
# manager: refs pin pages against eviction; pin_tier floor invalidates
# ---------------------------------------------------------------------------


def _page(kb: int, fill: float = 1.0) -> jax.Array:
    return jnp.full((kb * 256,), fill, jnp.float32)   # kb KiB


def _donate(mgr, tokens, n_pages, kb=256):
    return mgr.donate(np.asarray(tokens, np.int32), n_pages,
                      lambda p: {"L0.0": _page(kb, float(p))})


def test_donate_lookup_release_roundtrip():
    pool = default_pool()
    mgr = PrefixCacheManager(pool, page_size=2)
    assert _donate(mgr, [1, 2, 3, 4], 2, kb=1) == 2
    assert mgr.stats.donated_pages == 2 and len(mgr) == 2
    # re-donating the same prefix extracts nothing new
    assert _donate(mgr, [1, 2, 3, 4], 2, kb=1) == 0

    hit = mgr.lookup(_toks(1, 2, 3, 4, 9))
    assert hit is not None and hit.n_pages == 2 and hit.tokens == 4
    assert mgr.live_refs == 2
    np.testing.assert_array_equal(
        np.asarray(pool.get(hit.page_keys()[1]["L0.0"])),
        np.asarray(_page(1, 1.0)))
    # the match cap leaves at least one token to prefill
    short = mgr.lookup(_toks(1, 2, 3, 4), max_tokens=3)
    assert short is not None and short.n_pages == 1

    mgr.release(hit)
    mgr.release(hit)          # idempotent
    mgr.release(short)
    assert mgr.live_refs == 0 and mgr.stats.releases == 2
    assert mgr.lookup(_toks(5, 5, 5, 5)) is None
    assert mgr.stats.misses == 1

    mgr.close()
    mgr.close()               # idempotent
    assert len(pool.entries) == 0
    pool.close()


def test_eviction_skips_refd_pages_and_invalidates_once_on_final_release():
    """The satellite's pinning contract: a page with live refs is never a
    pool victim (two readers: releasing ONE keeps it pinned); after the
    FINAL release it becomes evictable, and the spill below the pin_tier
    floor fires the invalidation exactly once."""
    # device fits exactly one page; pin_tier="device" makes any spill an
    # invalidation
    pool = default_pool(device_capacity=256 * 1024)
    mgr = PrefixCacheManager(pool, page_size=2, pin_tier=DEVICE_TIER)
    assert _donate(mgr, [1, 2], 1) == 1
    key = next(iter(mgr.index.nodes.values())).entries["L0.0"]

    h1 = mgr.lookup(_toks(1, 2, 9))
    h2 = mgr.lookup(_toks(1, 2, 8))
    assert h1 is not None and h2 is not None and mgr.live_refs == 2

    # device pressure while ref'd: the pinned page is skipped — the
    # overflowing put fails rather than spilling it
    from repro.pool import PoolCapacityError
    with pytest.raises(PoolCapacityError):
        pool.put("pressure", _page(256), DEVICE_TIER, priority=99.0)
    assert pool.tier_of(key) == DEVICE_TIER

    mgr.release(h1)           # one of two readers: still pinned
    with pytest.raises(PoolCapacityError):
        pool.put("pressure", _page(256), DEVICE_TIER, priority=99.0)
    assert mgr.stats.invalidations == 0

    mgr.release(h2)           # FINAL release: unpinned, evictable
    pool.put("pressure", _page(256), DEVICE_TIER, priority=99.0)
    assert mgr.stats.invalidations == 1      # exactly once
    assert len(mgr) == 0
    assert mgr.lookup(_toks(1, 2, 9)) is None   # also flushes the drop
    assert key not in pool
    mgr.close()
    pool.close()


def test_pin_tier_floor_invalidates_whole_chain():
    """Default floor (host): host→remote spill of ONE page invalidates it
    AND every deeper page of its chain; device→host does not."""
    # device fits one page, host fits two: both donated pages can age down
    # to host (the floor) and remain valid
    pool = default_pool(device_capacity=256 * 1024, host_capacity=512 * 1024)
    mgr = PrefixCacheManager(pool, page_size=2, pin_tier=HOST_TIER)
    assert _donate(mgr, [1, 2, 3, 4], 2) == 2
    k1 = mgr.index.match(_toks(1, 2))[0].entries["L0.0"]

    # device→host spills — cold but still valid
    pool.put("p1", _page(256), DEVICE_TIER, priority=99.0)
    assert mgr.stats.invalidations == 0 and len(mgr) == 2
    assert pool.tier_of(k1) in (DEVICE_TIER, HOST_TIER)

    # host pressure pushes a page host→remote — below the floor: the owning
    # node and its descendant leave the index and the pool together
    pool.put("p2", _page(256), HOST_TIER, priority=99.0)
    assert mgr.stats.invalidations == 2
    assert len(mgr) == 0
    assert mgr.lookup(_toks(1, 2, 3, 4, 9)) is None   # flushes the drops
    assert k1 not in pool
    # the cascade left the tier accounting exact: only p1 + p2 remain
    assert pool.occupancy(DEVICE_TIER)[0] == 256 * 1024
    assert pool.occupancy(HOST_TIER)[0] == 256 * 1024
    assert pool.occupancy("remote")[0] == 0
    mgr.close()
    pool.close()


def test_max_pages_budget_evicts_coldest_leaf_first():
    pool = default_pool()
    mgr = PrefixCacheManager(pool, page_size=1, max_pages=2)
    assert _donate(mgr, [1], 1, kb=1) == 1
    assert _donate(mgr, [2], 1, kb=1) == 1
    mgr.release(mgr.lookup(_toks(1)))        # refresh: [2] is now coldest
    assert _donate(mgr, [3], 1, kb=1) == 1   # evicts [2]
    assert mgr.stats.evictions == 1 and len(mgr) == 2
    assert mgr.lookup(_toks(2)) is None

    # a budget full of ref'd pages rejects the donation instead
    ha = mgr.lookup(_toks(1))
    hb = mgr.lookup(_toks(3))
    assert _donate(mgr, [4], 1, kb=1) == 0
    assert mgr.stats.rejected_donations == 1 and len(mgr) == 2
    mgr.release(ha)
    mgr.release(hb)
    mgr.close()
    pool.close()


def test_manager_validation():
    pool = default_pool()
    with pytest.raises(ValueError, match="max_pages"):
        PrefixCacheManager(pool, page_size=2, max_pages=0)
    with pytest.raises(ValueError, match="min_match_pages"):
        PrefixCacheManager(pool, page_size=2, min_match_pages=0)
    with pytest.raises(ValueError, match="pin_tier"):
        PrefixCacheManager(pool, page_size=2, pin_tier="nvram")
    mgr = PrefixCacheManager(pool, page_size=2, min_match_pages=2)
    _donate(mgr, [1, 2], 1, kb=1)
    assert mgr.lookup(_toks(1, 2, 9)) is None    # 1 page < min_match_pages
    mgr.close()
    pool.close()


# ---------------------------------------------------------------------------
# scheduler integration: token identity + prefill savings
# ---------------------------------------------------------------------------


def _family_trace(n, prefix_len=12, seed=1):
    """Requests sharing one prompt prefix, arriving far enough apart that
    each retires (donates) before the next arrives."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, CFG.vocab_size, size=prefix_len, dtype=np.int32)
    reqs = []
    for i in range(n):
        sfx = rng.integers(0, CFG.vocab_size, size=int(rng.integers(3, 8)),
                           dtype=np.int32)
        reqs.append(Request(tokens=np.concatenate([pre, sfx]),
                            max_new_tokens=4, arrival=12.0 * i, seed=i))
    return reqs


def _reference(model, params, reqs):
    eng = ServeEngine(model, params, max_seq=MAX_SEQ)
    out = {r.req_id: np.asarray(
        eng.generate({"tokens": jnp.asarray(r.tokens[None, :])},
                     r.max_new_tokens, seed=r.seed))[0] for r in reqs}
    eng.close()
    return out


# NB: these scheduler tests use chunk_size=6 — test_sched's compile-count
# test asserts a jit-cache DELTA for its own chunk_size=8, and the chunk
# entry point is cached per model config, shared across test modules.


def test_prefix_hits_are_token_identical_resident(model_and_params):
    model, params = model_and_params
    reqs = _family_trace(3)
    pool = default_pool()
    mgr = PrefixCacheManager(pool, page_size=4)
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=6),
        pool=pool, prefix_cache=mgr)
    out = sched.run(reqs)
    assert sched.stats.prefix_hits == 2          # every request after the 1st
    assert sched.stats.prefix_hit_tokens == 2 * 12
    snap = mgr.snapshot()
    assert snap["hits"] == 2 and snap["donations"] >= 1
    assert snap["refs"] == 0                     # all released at retire
    # the cached tokens were never prefilled again
    total = sum(r.prompt_len for r in reqs)
    assert sched.stats.prefill_tokens == total - 2 * 12
    ref = _reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    sched.close()
    mgr.close()
    pool.close()


def test_prefix_hits_are_token_identical_kv_offload(model_and_params):
    """kv_offload under device pressure: prefix pages ride the pool tiers
    (and the PlanPrefetcher on fetch), shared pages survive the mid-prefill
    park/restore cycle, and outputs stay token-identical."""
    model, params = model_and_params
    reqs = _family_trace(3)
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=int(1.5 * row), host_capacity=6 * row,
                        transfer=TransferEngine(depth=64))
    mgr = PrefixCacheManager(pool, page_size=4)
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True,
                        chunk_size=6),
        pool=pool, prefix_cache=mgr)
    out = sched.run(reqs)
    assert sched.stats.prefix_hits == 2
    assert sched.stats.pages_parked > 0          # park/restore really ran
    assert pool.snapshot()["evictions"] > 0      # tiering pressure was real
    ref = _reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    sched.close()
    mgr.close()
    pool.close()


def test_prefix_requires_chunked_prefill(model_and_params):
    model, params = model_and_params
    pool = default_pool()
    mgr = PrefixCacheManager(pool, page_size=4)
    with pytest.raises(ValueError, match="chunk"):
        ContinuousScheduler(model, params,
                            SchedulerConfig(max_batch=2, max_seq=MAX_SEQ),
                            pool=pool, prefix_cache=mgr)
    # kv_offload mode must share the scheduler's pool
    other = default_pool()
    with pytest.raises(ValueError, match="pool"):
        ContinuousScheduler(
            model, params,
            SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True,
                            chunk_size=6),
            pool=other, prefix_cache=mgr)
    mgr.close()
    pool.close()
    other.close()


# ---------------------------------------------------------------------------
# front door: config block, session wiring, stats surface
# ---------------------------------------------------------------------------


def test_prefix_config_validation_and_roundtrip():
    cfg = OffloadConfig(
        mode="continuous", chunk_size=6,
        prefix_cache=PrefixCacheConfig(enable=True, page_size=4,
                                       max_pages=64, min_match_pages=2,
                                       pin_tier="device"))
    assert OffloadConfig.from_dict(cfg.to_dict()) == cfg
    # the block survives a JSON round trip too
    import json
    assert OffloadConfig.from_dict(json.loads(json.dumps(cfg.to_dict()))) \
        == cfg

    with pytest.raises(ValueError, match="chunk_size"):
        OffloadConfig(mode="continuous",
                      prefix_cache=PrefixCacheConfig(enable=True))
    with pytest.raises(ValueError, match="scheduler mode"):
        OffloadConfig(mode="resident", chunk_size=6,
                      prefix_cache=PrefixCacheConfig(enable=True))
    # tier names are declarative (the topology's), so pin_tier validates
    # at the OffloadConfig level against the effective chain — but only
    # when the cache is actually enabled
    with pytest.raises(ValueError, match="pin_tier"):
        OffloadConfig(mode="continuous", chunk_size=8,
                      prefix_cache=PrefixCacheConfig(enable=True,
                                                     pin_tier="nvram"))
    OffloadConfig(prefix_cache=PrefixCacheConfig(pin_tier="nvram"))
    with pytest.raises(ValueError, match="page_size"):
        PrefixCacheConfig(page_size=0)


def test_session_builds_and_surfaces_prefix_cache(model_and_params):
    model, params = model_and_params
    cfg = OffloadConfig(mode="continuous", max_batch=2, max_seq=MAX_SEQ,
                        chunk_size=6,
                        prefix_cache=PrefixCacheConfig(enable=True,
                                                       page_size=4))
    reqs = _family_trace(3)
    with HyperOffloadSession(cfg) as session:
        assert session.prefix_cache is not None
        sched = session.scheduler(model, params)
        out = sched.run(reqs)
        stats = session.stats()
        assert stats["prefix"]["hits"] == 2
        assert stats["prefix"]["donated_pages"] >= 1
        assert stats["sched"]["prefix_hits"] == 2
        assert stats["sched"]["prefix_hit_tokens"] == 24
    ref = _reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])

    # disabled (default) sessions surface no prefix block
    with HyperOffloadSession(OffloadConfig()) as session:
        assert session.prefix_cache is None
        assert session.stats()["prefix"] is None


# ---------------------------------------------------------------------------
# shared-prefix traces
# ---------------------------------------------------------------------------


def test_poisson_trace_shared_prefix_mode():
    tr = poisson_trace(12, rate=1.0, vocab_size=97, prompt_lens=(4, 8),
                       prompt_quantum=4, n_prefix_families=2, prefix_len=16,
                       seed=5)
    heads = {t.tokens[:16].tobytes() for t in tr}
    assert len(heads) == 2                       # exactly the two families
    for t in tr:
        assert t.prompt_len in (16 + 4, 16 + 8)  # prefix + on-grid suffix

    # disabled mode leaves seeded traces byte-identical to the old RNG path
    a = poisson_trace(6, rate=1.0, vocab_size=97, seed=3)
    b = poisson_trace(6, rate=1.0, vocab_size=97, n_prefix_families=None,
                      prefix_len=0, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.tokens, y.tokens)
        assert (x.arrival, x.max_new_tokens) == (y.arrival, y.max_new_tokens)

    with pytest.raises(ValueError, match="n_prefix_families"):
        poisson_trace(2, rate=1.0, vocab_size=97, n_prefix_families=0,
                      prefix_len=4)
    with pytest.raises(ValueError, match="prefix_len"):
        poisson_trace(2, rate=1.0, vocab_size=97, n_prefix_families=2)
