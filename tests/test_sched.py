"""Continuous-batching scheduler: token identity vs. sequential serving
(including mid-stream joins/retirements and host-tier eviction), admission
control never over-committing pool capacity, and plan-driven prefetch
issuing ahead of consumption."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.offload.kvcache import worst_case_page_bytes
from repro.pool import DEVICE_TIER, HOST_TIER, TransferEngine, default_pool
from repro.sched import (
    ContinuousScheduler, Request, SchedulerConfig, poisson_trace,
)
from repro.serving.engine import ServeEngine

CFG = REGISTRY["phi3-mini-3.8b"].reduced()
MAX_SEQ = 32


@pytest.fixture(scope="module")
def model_and_params():
    m = build_model(CFG)
    return m, m.init(jax.random.key(0))


def _mixed_trace():
    """Staggered arrivals + mixed lengths on a 2-slot batch: forces
    mid-stream joins, retirements, and continuous slot reuse."""
    rng = np.random.default_rng(0)
    shapes = [(5, 6, 0.0), (9, 3, 0.0), (3, 8, 2.0), (7, 1, 4.0), (4, 5, 4.0)]
    return [Request(tokens=rng.integers(0, CFG.vocab_size, size=s,
                                        dtype=np.int32),
                    max_new_tokens=n, arrival=a, seed=i)
            for i, (s, n, a) in enumerate(shapes)]


def _sequential_reference(model, params, requests, **kw):
    eng = ServeEngine(model, params, max_seq=MAX_SEQ)
    out = {}
    for r in requests:
        got = eng.generate({"tokens": jnp.asarray(r.tokens[None, :])},
                           r.max_new_tokens, seed=r.seed, **kw)
        out[r.req_id] = np.asarray(got)[0]
    eng.close()
    return out


def test_continuous_matches_sequential_greedy(model_and_params):
    model, params = model_and_params
    reqs = _mixed_trace()
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=2, max_seq=MAX_SEQ))
    out = sched.run(reqs)
    assert sched.stats.joins == len(reqs) and sched.stats.retires == len(reqs)
    # the 2-slot batch over 5 staggered requests must have reused slots
    assert sched.stats.steps < sum(r.max_new_tokens for r in reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    sched.close()
    sched.close()   # idempotent


def test_offload_matches_sequential_and_evicts_to_host(model_and_params):
    """kv_offload mode under device-tier pressure: cold sequences' pages
    spill to the host tier via the priority+LRU manager, fetches run
    through the plan, and outputs stay token-identical."""
    model, params = model_and_params
    reqs = _mixed_trace()
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=int(1.5 * row),
                        host_capacity=4 * row,
                        transfer=TransferEngine(depth=64))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    snap = sched.pool_stats()
    assert snap["evictions"] > 0 and sched.stats.cold_spills > 0
    assert snap["tier/remote"]["entries"] == 0       # admission held
    sched.close()
    pool.close()


def test_prefetcher_issues_ahead_of_consumption(model_and_params):
    """The plan schedules every layer's fetch before its consumer, and at
    runtime most waits find the transfer already complete — the
    store-then-immediately-wait round trip is gone from the decode loop."""
    model, params = model_and_params
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True))
    sched.run(_mixed_trace())
    pf = sched.prefetch_stats()
    assert pf["fetches_issued"] > 0
    assert pf["mean_plan_lead"] >= 1.0          # issued ahead in the plan
    tr = sched.pool_stats()["transfer"]
    assert tr["issued"] == pf["fetches_issued"]
    assert tr["waits_overlapped"] > 0           # overlapped at runtime too
    sched.close()


def test_temperature_sampling_matches_batch1_engine(model_and_params):
    """For temperature>0 the scheduler reproduces a batch-1 engine run's
    key stream (first token from the raw seed key, one split per step)."""
    model, params = model_and_params
    rng = np.random.default_rng(3)
    reqs = [Request(tokens=rng.integers(0, CFG.vocab_size, size=s,
                                        dtype=np.int32),
                    max_new_tokens=4, temperature=0.8, top_k=8, seed=i)
            for i, s in enumerate((5, 8))]
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=2, max_seq=MAX_SEQ))
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs,
                                temperature=0.8, top_k=8)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    sched.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def _run_checking_invariants(model, params, reqs, slots, device_rows,
                             host_rows):
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=device_rows * row,
                        host_capacity=host_rows * row,
                        transfer=TransferEngine(depth=64))
    cap = device_rows * row + host_rows * row
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=slots, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    for r in reqs:
        sched.submit(r)
    guard = 0
    max_active = 0
    while len(sched.queue) or sched.active:
        if not sched.active and sched.queue.head_ready(sched.now) is None:
            sched.now = sched.queue.next_arrival()
        sched.step()
        max_active = max(max_active, len(sched.active))
        # over-commit invariants, checked EVERY step:
        assert sched.pool.reserved_bytes((DEVICE_TIER, HOST_TIER)) <= cap
        snap = sched.pool.snapshot()
        assert snap["tier/remote"]["entries"] == 0, \
            "pages forced into the remote tier — admission over-committed"
        guard += 1
        assert guard < 500
    assert len(sched.finished) == len(reqs)
    assert max_active <= device_rows + host_rows   # ≤ capacity in rows
    assert sched.pool.reserved_bytes() == 0      # all released at retirement
    sched.close()
    pool.close()
    return sched


def test_admission_never_overcommits_deterministic(model_and_params):
    model, params = model_and_params
    blocked = 0
    for seed in range(3):
        # rate 5.0 clusters arrivals so a 3rd request contends while two
        # (the whole device+host capacity) are running
        reqs = poisson_trace(6, rate=5.0, vocab_size=CFG.vocab_size,
                             prompt_lens=(4, 8), new_tokens=(1, 4),
                             prompt_quantum=4, seed=seed)
        sched = _run_checking_invariants(model, params, reqs,
                                         slots=3, device_rows=1, host_rows=1)
        blocked += sched.admission.blocked
    assert blocked > 0    # 3 slots but capacity for 2 → admission gated


@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(1, 2),
       st.integers(1, 2))
@settings(max_examples=8, deadline=None)
def test_admission_never_overcommits_property(seed, n_reqs, device_rows,
                                              host_rows):
    m = build_model(CFG)
    params = m.init(jax.random.key(0))
    reqs = poisson_trace(n_reqs, rate=2.0, vocab_size=CFG.vocab_size,
                         prompt_lens=(4, 8), new_tokens=(1, 3),
                         prompt_quantum=4, seed=seed)
    _run_checking_invariants(m, params, reqs, slots=3,
                             device_rows=device_rows, host_rows=host_rows)


def test_covered_reservations_allow_full_concurrency(model_and_params):
    """A running request's parked pages are charged via its reservation
    (``covers``), not double-counted as occupancy: capacity for exactly two
    worst-case rows really admits two concurrent requests."""
    model, params = model_and_params
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=row, host_capacity=row,
                        transfer=TransferEngine(depth=64))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, prefill_budget=2,
                        kv_offload=True),
        pool=pool)
    reqs = [Request(tokens=np.ones((4,), np.int32), max_new_tokens=6, seed=i)
            for i in range(2)]
    for r in reqs:
        sched.submit(r)
    for _ in range(3):
        sched.step()
    assert len(sched.active) == 2       # both admitted, despite parked pages
    sched.run()
    assert sched.pool.snapshot()["tier/remote"]["entries"] == 0
    sched.close()
    pool.close()


def test_arrival_queue_orders_by_arrival_not_submission(model_and_params):
    """A future-dated request submitted first must not shadow an
    already-arrived later submission."""
    model, params = model_and_params
    late = Request(tokens=np.ones((4,), np.int32), max_new_tokens=2,
                   arrival=50.0, seed=0)
    early = Request(tokens=np.ones((4,), np.int32), max_new_tokens=2,
                    arrival=0.0, seed=1)
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=1, max_seq=MAX_SEQ))
    sched.submit(late)
    sched.submit(early)
    sched.run()
    assert sched.finished[early.req_id].t_done < 50.0   # served before late
    sched.close()


def test_oversized_request_raises(model_and_params):
    model, params = model_and_params
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=1, max_seq=MAX_SEQ))
    with pytest.raises(ValueError, match="exceeds max_seq"):
        sched.submit(Request(tokens=np.ones((MAX_SEQ,), np.int32),
                             max_new_tokens=4))
    sched.close()


def test_unadmittable_request_raises(model_and_params):
    """A request whose worst-case pages exceed device+host capacity must
    fail loudly, not deadlock the queue."""
    model, params = model_and_params
    pool = default_pool(device_capacity=64, host_capacity=64)
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    sched.submit(Request(tokens=np.ones((4,), np.int32), max_new_tokens=2))
    with pytest.raises(RuntimeError, match="never be admitted"):
        sched.step()
    sched.close()
    pool.close()


# ---------------------------------------------------------------------------
# engine round-trip key churn fix
# ---------------------------------------------------------------------------


def test_engine_round_trip_uses_stable_keys(model_and_params):
    model, params = model_and_params
    eng = ServeEngine(model, params, max_seq=MAX_SEQ, offload_kv=True)
    toks = jnp.ones((1, 4), jnp.int32)
    eng.generate({"tokens": toks}, 5)
    snap = eng.pool_stats()
    n_leaves = len(jax.tree.leaves(model.init_cache(1, MAX_SEQ)))
    # stable keys: 4 round trips re-put the same leaf entries; the only
    # drops are the end-of-generate release (≤ one per leaf, not per step)
    assert eng.stats.cache_round_trips == 4
    assert snap["puts"] == 4 * n_leaves
    assert snap["drops"] <= n_leaves
    assert snap["tier/host"]["entries"] == 0     # released after generate
    eng.close()
    eng.close()   # idempotent
