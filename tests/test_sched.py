"""Continuous-batching scheduler: token identity vs. sequential serving
(including mid-stream joins/retirements and host-tier eviction), admission
control never over-committing pool capacity, and plan-driven prefetch
issuing ahead of consumption."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.offload.kvcache import worst_case_page_bytes
from repro.pool import DEVICE_TIER, HOST_TIER, TransferEngine, default_pool
from repro.sched import (
    ArrivalQueue, ContinuousScheduler, Request, SchedulerConfig,
    poisson_trace,
)
from repro.serving.engine import ServeEngine, jit_prefill_chunk

CFG = REGISTRY["phi3-mini-3.8b"].reduced()
MAX_SEQ = 32


@pytest.fixture(scope="module")
def model_and_params():
    m = build_model(CFG)
    return m, m.init(jax.random.key(0))


def _mixed_trace():
    """Staggered arrivals + mixed lengths on a 2-slot batch: forces
    mid-stream joins, retirements, and continuous slot reuse."""
    rng = np.random.default_rng(0)
    shapes = [(5, 6, 0.0), (9, 3, 0.0), (3, 8, 2.0), (7, 1, 4.0), (4, 5, 4.0)]
    return [Request(tokens=rng.integers(0, CFG.vocab_size, size=s,
                                        dtype=np.int32),
                    max_new_tokens=n, arrival=a, seed=i)
            for i, (s, n, a) in enumerate(shapes)]


def _sequential_reference(model, params, requests, **kw):
    eng = ServeEngine(model, params, max_seq=MAX_SEQ)
    out = {}
    for r in requests:
        got = eng.generate({"tokens": jnp.asarray(r.tokens[None, :])},
                           r.max_new_tokens, seed=r.seed, **kw)
        out[r.req_id] = np.asarray(got)[0]
    eng.close()
    return out


def test_continuous_matches_sequential_greedy(model_and_params):
    model, params = model_and_params
    reqs = _mixed_trace()
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=2, max_seq=MAX_SEQ))
    out = sched.run(reqs)
    assert sched.stats.joins == len(reqs) and sched.stats.retires == len(reqs)
    # the 2-slot batch over 5 staggered requests must have reused slots
    assert sched.stats.steps < sum(r.max_new_tokens for r in reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    sched.close()
    sched.close()   # idempotent


def test_offload_matches_sequential_and_evicts_to_host(model_and_params):
    """kv_offload mode under device-tier pressure: cold sequences' pages
    spill to the host tier via the priority+LRU manager, fetches run
    through the plan, and outputs stay token-identical."""
    model, params = model_and_params
    reqs = _mixed_trace()
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=int(1.5 * row),
                        host_capacity=4 * row,
                        transfer=TransferEngine(depth=64))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    snap = sched.pool_stats()
    assert snap["evictions"] > 0 and sched.stats.cold_spills > 0
    assert snap["tier/remote"]["entries"] == 0       # admission held
    sched.close()
    pool.close()


@pytest.mark.parametrize("codec", ["int8", "fp8"])
def test_offload_with_kv_codec_stays_token_identical(model_and_params, codec):
    """Quantized KV pages through the full continuous-scheduler
    park/restore path: the same device-pressure trace as above, but
    spilled pages round-trip through the codec host tier. On this trace
    the quantization noise flips no greedy tokens (pinned empirically —
    the hard requirement is the bounded codec round-trip, exercised end
    to end), and the on-wire spill traffic shrinks ~4× for fp32 pages."""
    model, params = model_and_params
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))

    def _run(name):
        pool = default_pool(device_capacity=int(1.5 * row),
                            host_capacity=4 * row,
                            transfer=TransferEngine(depth=64),
                            codec=name, codec_below="host")
        sched = ContinuousScheduler(
            model, params,
            SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True),
            pool=pool)
        reqs = _mixed_trace()
        raw = sched.run(reqs)
        out = {r.seed: raw[r.req_id] for r in reqs}
        snap = sched.pool_stats()
        sched.close()
        pool.close()
        return out, snap

    exact, snap0 = _run(None)
    quant, snap1 = _run(codec)
    assert snap1["evictions"] > 0                 # pressure actually spilled
    for seed in exact:
        np.testing.assert_array_equal(quant[seed], exact[seed])
    spill0 = snap0["transfer"]["pairs"]["device->host"]["bytes"]
    spill1 = snap1["transfer"]["pairs"]["device->host"]["bytes"]
    assert spill1 * 2 <= spill0                   # >= 2x wire-byte reduction


def test_prefetcher_issues_ahead_of_consumption(model_and_params):
    """The plan schedules every layer's fetch before its consumer, and at
    runtime most waits find the transfer already complete — the
    store-then-immediately-wait round trip is gone from the decode loop."""
    model, params = model_and_params
    pool = default_pool()
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    sched.run(_mixed_trace())
    pf = sched.prefetch_stats()
    assert pf["fetches_issued"] > 0
    assert pf["mean_plan_lead"] >= 1.0          # issued ahead in the plan
    tr = sched.pool_stats()["transfer"]
    assert tr["issued"] == pf["fetches_issued"]
    assert tr["waits_overlapped"] > 0           # overlapped at runtime too
    sched.close()
    pool.close()


def test_temperature_sampling_matches_batch1_engine(model_and_params):
    """For temperature>0 the scheduler reproduces a batch-1 engine run's
    key stream (first token from the raw seed key, one split per step)."""
    model, params = model_and_params
    rng = np.random.default_rng(3)
    reqs = [Request(tokens=rng.integers(0, CFG.vocab_size, size=s,
                                        dtype=np.int32),
                    max_new_tokens=4, temperature=0.8, top_k=8, seed=i)
            for i, s in enumerate((5, 8))]
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=2, max_seq=MAX_SEQ))
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs,
                                temperature=0.8, top_k=8)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    sched.close()


# ---------------------------------------------------------------------------
# chunked cache-aware prefill
# ---------------------------------------------------------------------------


def _long_trace():
    """Short and long prompts interleaved on a 2-slot batch: long prompts
    span several chunks, so PREFILL persists across steps while other
    requests join, decode, and retire around it."""
    rng = np.random.default_rng(1)
    shapes = [(5, 6, 0.0), (20, 3, 0.0), (9, 4, 2.0), (23, 2, 4.0),
              (4, 5, 4.0)]
    return [Request(tokens=rng.integers(0, CFG.vocab_size, size=s,
                                        dtype=np.int32),
                    max_new_tokens=n, arrival=a, seed=i)
            for i, (s, n, a) in enumerate(shapes)]


def _chunked_identity(model, params, chunk, **cfg_kw):
    reqs = _long_trace()
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=chunk,
                        **cfg_kw))
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    return sched


def test_chunked_prefill_matches_whole_prompt(model_and_params):
    """chunk_size=4: every prompt spans multiple chunks; outputs must be
    token-identical to sequential whole-prompt serving across joins and
    retires."""
    model, params = model_and_params
    sched = _chunked_identity(model, params, 4)
    # long prompts really advanced chunk-by-chunk across steps
    assert sched.stats.prefill_chunks > sched.stats.joins
    assert sched.stats.prefill_tokens == sum(
        st.request.prompt_len for st in sched.finished.values())
    sched.close()


@pytest.mark.slow
@pytest.mark.parametrize("chunk", [16, MAX_SEQ])
def test_chunked_prefill_matches_whole_prompt_coarse(model_and_params, chunk):
    """Coarser chunks (including chunk_size == max_seq, the whole-prompt-
    in-one-chunk degenerate case) stay token-identical."""
    model, params = model_and_params
    _chunked_identity(model, params, chunk).close()


@pytest.mark.slow
def test_chunked_prefill_kv_offload_identity(model_and_params):
    """Chunked prefill under kv_offload with a tight device tier: partial
    chunk rows park/restore through the pool between steps, cold pages
    spill to host, and outputs stay token-identical."""
    model, params = model_and_params
    reqs = _long_trace()
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=int(1.5 * row),
                        host_capacity=4 * row,
                        transfer=TransferEngine(depth=64))
    # prefill_tokens=8 > chunk_size exercises multi-chunk advancement per
    # step (row held resident across chunks, parked once per step)
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=4,
                        prefill_tokens=8, kv_offload=True),
        pool=pool)
    out = sched.run(reqs)
    ref = _sequential_reference(model, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(out[r.req_id], ref[r.req_id])
    # mid-prefill rows really were parked page-by-page (pages_parked counts
    # both prefill parks and decode parks; chunks > joins ⇒ prefill parked)
    assert sched.stats.prefill_chunks > sched.stats.joins
    snap = sched.pool_stats()
    assert snap["evictions"] > 0
    assert snap["tier/remote"]["entries"] == 0       # admission held
    sched.close()
    pool.close()


def test_chunked_prefill_compiles_once(model_and_params):
    """Mixed prompt lengths through one chunk shape compile exactly ONE
    prefill executable — the structural fix for whole-prompt prefill's
    per-length compile churn. (chunk_size=8 is used by no other test, so
    the jit cache delta is exactly this test's compiles.)"""
    model, params = model_and_params
    fn = jit_prefill_chunk(model)
    if not hasattr(fn, "_cache_size"):
        pytest.skip("jax jit cache-size introspection unavailable")
    before = fn._cache_size()
    rng = np.random.default_rng(2)
    reqs = [Request(tokens=rng.integers(0, CFG.vocab_size, size=s,
                                        dtype=np.int32),
                    max_new_tokens=2, seed=i)
            for i, s in enumerate((5, 9, 14, 23, 26))]   # 5 distinct lengths
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=8))
    sched.run(reqs)
    assert fn._cache_size() - before == 1
    sched.close()


def test_chunked_prefill_token_budget_bounds_step(model_and_params):
    """prefill_tokens is a per-step token budget: with the default (one
    chunk) no step advances prefill by more than chunk_size tokens, even
    when a long prompt is waiting — the whole-prompt stall is gone."""
    model, params = model_and_params
    reqs = _long_trace()
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=4))
    for r in reqs:
        sched.submit(r)
    max_step_prefill = 0
    while len(sched.queue) or sched.active:
        if not sched.active and sched.queue.head_ready(sched.now) is None:
            sched.now = max(sched.now, sched.queue.next_arrival())
        before = sched.stats.prefill_tokens
        sched.step()
        max_step_prefill = max(max_step_prefill,
                               sched.stats.prefill_tokens - before)
    assert 0 < max_step_prefill <= 4
    # a doubled budget admits two chunks per step
    sched2 = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, chunk_size=4,
                        prefill_tokens=8))
    sched2.run(_long_trace())
    assert sched2.stats.steps < sched.stats.steps
    sched.close()
    sched2.close()


def test_chunked_long_prompts_do_not_trip_progress_guard(model_and_params):
    """Many long prompts at one chunk per step exceed the old
    decode-budget-only max_steps bound; the chunk-aware bound (ceil(prompt
    / chunk) extra steps per request) must let them complete."""
    model, params = model_and_params
    toks = np.ones((28,), np.int32)
    reqs = [Request(tokens=toks, max_new_tokens=1, seed=i) for i in range(8)]
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, chunk_size=4))
    out = sched.run(reqs)                     # default max_steps — no raise
    assert len(out) == len(reqs)
    # 8 requests x ceil(28/4)=7 chunk steps alone exceed the old bound of
    # 16 + 2*sum(max_new+1) = 48
    assert sched.stats.steps > 48
    sched.close()


def test_chunked_prefill_rejects_unsupported_models(model_and_params):
    model, params = model_and_params
    ssm_cfg = REGISTRY["mamba2-370m"].reduced()
    ssm = build_model(ssm_cfg)
    ssm_params = ssm.init(jax.random.key(0))
    with pytest.raises(ValueError, match="chunked prefill"):
        ContinuousScheduler(
            ssm, ssm_params,
            SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, chunk_size=4))
    with pytest.raises(ValueError, match="chunk_size"):
        ContinuousScheduler(
            model, params,
            SchedulerConfig(max_batch=1, max_seq=MAX_SEQ,
                            chunk_size=MAX_SEQ + 1))
    with pytest.raises(ValueError, match="requires chunk_size"):
        ContinuousScheduler(
            model, params,
            SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, prefill_tokens=8))


# ---------------------------------------------------------------------------
# arrival queue + trace generator
# ---------------------------------------------------------------------------


def test_arrival_queue_insort_scales_and_orders():
    """Regression for the O(n^2 log n) full re-sort per push: several
    thousand submits in adversarial (reverse-arrival) order stay cheap and
    come out ordered by (arrival, req_id) via the public accessor."""
    import time as _time
    q = ArrivalQueue()
    toks = np.ones((2,), np.int32)
    rng = np.random.default_rng(0)
    arrivals = np.concatenate([np.linspace(100.0, 0.0, 2000),
                               rng.uniform(0.0, 100.0, 2000)])
    t0 = _time.perf_counter()
    for a in arrivals:
        q.push(Request(tokens=toks, max_new_tokens=1, arrival=float(a)))
    elapsed = _time.perf_counter() - t0
    pend = q.pending()
    assert len(pend) == len(q) == 4000
    keys = [(s.request.arrival, s.req_id) for s in pend]
    assert keys == sorted(keys)
    assert elapsed < 5.0          # generous; the old path was ~quadratic


def test_poisson_trace_quantum_grid():
    """Prompt lengths land ON the quantum grid even when the range bounds
    are off-grid (the old round-down emitted the off-grid lower bound)."""
    tr = poisson_trace(64, rate=1.0, vocab_size=128, prompt_lens=(6, 21),
                       prompt_quantum=4, seed=0)
    lens = sorted({r.prompt_len for r in tr})
    assert all(l % 4 == 0 for l in lens)
    # ceil grid of lo=6, clamped at hi=21's grid floor — a caller sizing
    # hi against max_seq must never receive a longer prompt than asked
    assert lens[0] >= 8 and lens[-1] <= 20


def test_poisson_trace_rejects_oversized_quantum():
    """No on-grid length exists past a range's upper bound — emitting a
    longer-than-asked prompt would overflow callers' max_seq sizing."""
    with pytest.raises(ValueError, match="prompt_quantum"):
        poisson_trace(4, rate=1.0, vocab_size=128, prompt_lens=(2, 6),
                      prompt_quantum=8, seed=0)
    with pytest.raises(ValueError, match="long_prompt_lens"):
        poisson_trace(4, rate=1.0, vocab_size=128, prompt_lens=(8, 16),
                      long_prompt_lens=(2, 6), long_fraction=0.5,
                      prompt_quantum=8, seed=0)


def test_poisson_trace_interactive_annotations():
    """Mixed interactive/batch mode: every request carries a spec, the
    interactive share is ~the asked fraction, and custom specs pass
    through untouched."""
    from repro.slo import SLOSpec
    tr = poisson_trace(64, rate=1.0, vocab_size=128,
                       interactive_fraction=0.35, seed=0)
    classes = [r.slo.priority_class for r in tr]
    assert set(classes) == {"interactive", "batch"}
    assert 0.15 < classes.count("interactive") / len(tr) < 0.55
    for r in tr:
        if r.slo.priority_class == "interactive":
            assert r.slo.ttft_deadline == 8.0       # default tight TTFT
        else:
            assert r.slo.ttft_deadline is None      # batch: throughput only
    custom = poisson_trace(
        16, rate=1.0, vocab_size=128, interactive_fraction=0.5,
        interactive_slo=SLOSpec("interactive", ttft_deadline=3.0),
        batch_slo=SLOSpec("standard", tpot_deadline=2.0), seed=0)
    for r in custom:
        assert r.slo.ttft_deadline == 3.0 \
            or r.slo.tpot_deadline == 2.0
    with pytest.raises(ValueError, match="interactive_fraction"):
        poisson_trace(4, rate=1.0, vocab_size=128,
                      interactive_fraction=1.5, seed=0)


def test_poisson_trace_annotations_off_is_byte_identical():
    """With interactive_fraction=None the RNG call sequence is unchanged:
    tokens/lengths/arrivals match an annotated trace of the same seed
    draw for draw (the class draw comes after all existing draws)."""
    a = poisson_trace(8, rate=1.0, vocab_size=128, seed=3)
    b = poisson_trace(8, rate=1.0, vocab_size=128, seed=3,
                      interactive_fraction=0.9)
    assert all(r.slo is None for r in a)
    for x, y in zip(a, b):
        assert x.arrival == y.arrival
        assert x.max_new_tokens == y.max_new_tokens
        np.testing.assert_array_equal(x.tokens, y.tokens)


def test_poisson_trace_long_tail():
    long = poisson_trace(64, rate=1.0, vocab_size=128, prompt_lens=(4, 8),
                         long_prompt_lens=(40, 48), long_fraction=0.5,
                         prompt_quantum=4, seed=0)
    lens = [r.prompt_len for r in long]
    assert any(l >= 40 for l in lens) and any(l <= 8 for l in lens)
    assert all(l % 4 == 0 for l in lens)
    # RNG call sequence is unchanged while the tail is disabled
    a = poisson_trace(8, rate=1.0, vocab_size=128, seed=3)
    b = poisson_trace(8, rate=1.0, vocab_size=128, seed=3, long_fraction=0.9)
    for x, y in zip(a, b):
        assert x.arrival == y.arrival
        np.testing.assert_array_equal(x.tokens, y.tokens)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def _run_checking_invariants(model, params, reqs, slots, device_rows,
                             host_rows):
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=device_rows * row,
                        host_capacity=host_rows * row,
                        transfer=TransferEngine(depth=64))
    cap = device_rows * row + host_rows * row
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=slots, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    for r in reqs:
        sched.submit(r)
    guard = 0
    max_active = 0
    while len(sched.queue) or sched.active:
        if not sched.active and sched.queue.head_ready(sched.now) is None:
            sched.now = sched.queue.next_arrival()
        sched.step()
        max_active = max(max_active, len(sched.active))
        # over-commit invariants, checked EVERY step:
        assert sched.pool.reserved_bytes((DEVICE_TIER, HOST_TIER)) <= cap
        snap = sched.pool.snapshot()
        assert snap["tier/remote"]["entries"] == 0, \
            "pages forced into the remote tier — admission over-committed"
        guard += 1
        assert guard < 500
    assert len(sched.finished) == len(reqs)
    assert max_active <= device_rows + host_rows   # ≤ capacity in rows
    assert sched.pool.reserved_bytes() == 0      # all released at retirement
    sched.close()
    pool.close()
    return sched


def test_admission_never_overcommits_deterministic(model_and_params):
    model, params = model_and_params
    blocked = 0
    for seed in range(3):
        # rate 5.0 clusters arrivals and decode budgets of 3-8 steps keep
        # capacity held, so a 3rd request contends while two (the whole
        # device+host capacity) are running
        reqs = poisson_trace(6, rate=5.0, vocab_size=CFG.vocab_size,
                             prompt_lens=(4, 8), new_tokens=(3, 8),
                             prompt_quantum=4, seed=seed)
        sched = _run_checking_invariants(model, params, reqs,
                                         slots=3, device_rows=1, host_rows=1)
        blocked += sched.admission.blocked
    assert blocked > 0    # 3 slots but capacity for 2 → admission gated


@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(1, 2),
       st.integers(1, 2))
@settings(max_examples=8, deadline=None)
def test_admission_never_overcommits_property(seed, n_reqs, device_rows,
                                              host_rows):
    m = build_model(CFG)
    params = m.init(jax.random.key(0))
    reqs = poisson_trace(n_reqs, rate=2.0, vocab_size=CFG.vocab_size,
                         prompt_lens=(4, 8), new_tokens=(1, 3),
                         prompt_quantum=4, seed=seed)
    _run_checking_invariants(m, params, reqs, slots=3,
                             device_rows=device_rows, host_rows=host_rows)


def test_covered_reservations_allow_full_concurrency(model_and_params):
    """A running request's parked pages are charged via its reservation
    (``covers``), not double-counted as occupancy: capacity for exactly two
    worst-case rows really admits two concurrent requests."""
    model, params = model_and_params
    row = worst_case_page_bytes(model.cache_specs(1, MAX_SEQ, jnp.float32))
    pool = default_pool(device_capacity=row, host_capacity=row,
                        transfer=TransferEngine(depth=64))
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=2, max_seq=MAX_SEQ, prefill_budget=2,
                        kv_offload=True),
        pool=pool)
    reqs = [Request(tokens=np.ones((4,), np.int32), max_new_tokens=6, seed=i)
            for i in range(2)]
    for r in reqs:
        sched.submit(r)
    for _ in range(3):
        sched.step()
    assert len(sched.active) == 2       # both admitted, despite parked pages
    sched.run()
    assert sched.pool.snapshot()["tier/remote"]["entries"] == 0
    sched.close()
    pool.close()


def test_arrival_queue_orders_by_arrival_not_submission(model_and_params):
    """A future-dated request submitted first must not shadow an
    already-arrived later submission."""
    model, params = model_and_params
    late = Request(tokens=np.ones((4,), np.int32), max_new_tokens=2,
                   arrival=50.0, seed=0)
    early = Request(tokens=np.ones((4,), np.int32), max_new_tokens=2,
                    arrival=0.0, seed=1)
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=1, max_seq=MAX_SEQ))
    sched.submit(late)
    sched.submit(early)
    sched.run()
    assert sched.finished[early.req_id].t_done < 50.0   # served before late
    sched.close()


def test_oversized_request_raises(model_and_params):
    model, params = model_and_params
    sched = ContinuousScheduler(model, params,
                                SchedulerConfig(max_batch=1, max_seq=MAX_SEQ))
    with pytest.raises(ValueError, match="exceeds max_seq"):
        sched.submit(Request(tokens=np.ones((MAX_SEQ,), np.int32),
                             max_new_tokens=4))
    sched.close()


def test_unadmittable_request_raises(model_and_params):
    """A request whose worst-case pages exceed device+host capacity must
    fail loudly, not deadlock the queue."""
    model, params = model_and_params
    pool = default_pool(device_capacity=64, host_capacity=64)
    sched = ContinuousScheduler(
        model, params,
        SchedulerConfig(max_batch=1, max_seq=MAX_SEQ, kv_offload=True),
        pool=pool)
    sched.submit(Request(tokens=np.ones((4,), np.int32), max_new_tokens=2))
    with pytest.raises(RuntimeError, match="never be admitted"):
        sched.step()
    sched.close()
    pool.close()


# ---------------------------------------------------------------------------
# engine round-trip key churn fix
# ---------------------------------------------------------------------------


def test_engine_round_trip_uses_stable_keys(model_and_params):
    model, params = model_and_params
    pool = default_pool()
    eng = ServeEngine(model, params, max_seq=MAX_SEQ, offload_kv=True,
                      pool=pool)
    toks = jnp.ones((1, 4), jnp.int32)
    eng.generate({"tokens": toks}, 5)
    snap = eng.pool_stats()
    n_leaves = len(jax.tree.leaves(model.init_cache(1, MAX_SEQ)))
    # stable keys: 4 round trips re-put the same leaf entries; the only
    # drops are the end-of-generate release (≤ one per leaf, not per step)
    assert eng.stats.cache_round_trips == 4
    assert snap["puts"] == 4 * n_leaves
    assert snap["drops"] <= n_leaves
    assert snap["tier/host"]["entries"] == 0     # released after generate
    eng.close()
    eng.close()   # idempotent
    pool.close()
