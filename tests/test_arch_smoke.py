"""Per-architecture smoke tests: REDUCED variant of each assigned arch —
one forward/train step on CPU, asserting output shapes and no NaNs, plus
prefill+decode consistency with the full forward."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, REGISTRY
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model
from repro.training.step import TrainStepConfig, init_train_state, make_train_step


def make_batch(cfg, b=2, s=16, key=1):
    toks = jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.frontend == "audio":
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (b, cfg.encoder.n_frames, cfg.d_model))
    if cfg.frontend == "vision":
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (b, s, cfg.d_model))
        batch["vision_mask"] = jnp.zeros((b, s), bool).at[:, :4].set(True)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None, :], (3, b, s)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_no_nans(arch):
    cfg = REGISTRY[arch].reduced()
    # zamba2's irreducible hybrid pattern is 6 layers (5 mamba + 1 attn) + a
    # 1-layer epilogue segment — everything else reduces to ≤ 2 layers
    assert cfg.n_layers <= 7 and cfg.d_model <= 256
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch = make_batch(cfg)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = REGISTRY[arch].reduced()
    m = build_model(cfg)
    ts = TrainStepConfig(warmup=1, total_steps=4, peak_lr=1e-3)
    params, opt = init_train_state(m, jax.random.key(0), ts=ts)
    step = make_train_step(m, ts)
    batch = make_batch(cfg)
    p0 = jax.tree.leaves(params)[0].copy()
    params, opt, metrics = step(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    p1 = jax.tree.leaves(params)[0]
    assert not bool(jnp.all(p0 == p1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = REGISTRY[arch].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    b, s = 2, 12
    batch = make_batch(cfg, b, s)
    logits_full, _ = m.forward(params, batch)

    pre = {k: (v[:, :s - 1] if k in ("tokens", "vision_embeds", "vision_mask")
               else v) for k, v in batch.items() if k != "targets"}
    if "positions" in pre:
        pre["positions"] = batch["positions"][:, :, :s - 1]
    cache = m.init_cache(b, s)
    lg_pre, cache = m.prefill(params, pre, cache)
    assert jnp.max(jnp.abs(lg_pre[:, 0] - logits_full[:, s - 2])) < 1e-3

    lg_dec, cache = m.decode_step(params, cache,
                                  batch["tokens"][:, s - 1:s], jnp.int32(s - 1))
    assert jnp.max(jnp.abs(lg_dec[:, 0] - logits_full[:, s - 1])) < 1e-3


def test_training_learns_synthetic_structure():
    """A real (small) model trained briefly on the synthetic Markov stream
    must beat the uniform-loss floor by a wide margin."""
    cfg = REGISTRY["phi3-mini-3.8b"].reduced()
    m = build_model(cfg)
    ts = TrainStepConfig(warmup=5, total_steps=60, peak_lr=2e-3)
    params, opt = init_train_state(m, jax.random.key(0), ts=ts)
    step = make_train_step(m, ts)
    data = SyntheticTokens(cfg.vocab_size, seq_len=32, global_batch=8, noise=0.05)
    first = last = None
    for i in range(60):
        params, opt, metrics = step(params, opt, data.batch(i))
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 1.0, (first, last)
