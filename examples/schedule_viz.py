"""Visualize HyperOffload's graph-driven execution-order optimization
(the paper's Figures 3/4) as ASCII timelines.

    PYTHONPATH=src python examples/schedule_viz.py

Builds a layer chain with pool-resident weights, plans it three ways —
(a) reactive runtime swapping, (b) operatorized but adversarially-early
prefetch order (Fig. 4b), (c) Algorithm-1-refined just-in-time order
(Fig. 4c) — and prints compute/DMA lanes plus peak memory for each.
"""

from repro.core import insertion, memsim, schedule, timeline
from repro.core.costmodel import TPU_V5E
from repro.core.ir import Graph


def build_chain(n=6, wbytes=256 << 20, flops=2e12):
    g = Graph()
    g.add_tensor("x", 1 << 20)
    prev = "x"
    for i in range(n):
        g.add_tensor(f"w{i}", wbytes, "weight", "remote")
        g.add_tensor(f"h{i}", 1 << 20)
        g.compute(f"f{i}", inputs=(prev, f"w{i}"), outputs=(f"h{i}",),
                  flops=flops, hbm_bytes=1e6)
        prev = f"h{i}"
    return g


def ascii_timeline(tl, width=78):
    total = tl.total
    lanes = {"compute": [], "r2d": [], "d2r": []}
    for name, (s, e, stream) in tl.schedule.items():
        if stream in lanes and e > s:
            lanes[stream].append((s, e, name))
    out = []
    for lane, items in lanes.items():
        if not items:
            continue
        row = [" "] * width
        for s, e, name in sorted(items):
            a = int(s / total * (width - 1))
            b = max(a + 1, int(e / total * (width - 1)))
            ch = name.split("::")[-1][0] if "::" in name else name[1]
            for i in range(a, min(b, width)):
                row[i] = ch if row[i] == " " else "#"
        out.append(f"  {lane:8s} |{''.join(row)}|")
    return "\n".join(out)


def main():
    g = build_chain()
    hw = TPU_V5E

    print("=== (a) reactive runtime swapping (paper §3.1) ===")
    cap = 3 * (256 << 20)
    tl_re = timeline.simulate_reactive(g.residentize(), hw, cap)
    print(f"  total {tl_re.total * 1e3:.1f} ms, {tl_re.stalls} synchronous "
          f"stalls, exposed {tl_re.exposed_comm * 1e3:.1f} ms\n")

    g2 = insertion.insert_cache_ops(g, hw)

    print("=== (b) operatorized, adversarial early-prefetch order (Fig. 4b) ===")
    pre = [n for n in g2.order() if g2.nodes[n].kind == "prefetch"]
    rest = [n for n in g2.order() if g2.nodes[n].kind != "prefetch"]
    early = pre + rest
    tl_e = timeline.simulate(g2, hw, early)
    mem_e = memsim.simulate(g2, early)
    print(f"  total {tl_e.total * 1e3:.1f} ms, exposed "
          f"{tl_e.exposed_comm * 1e3:.1f} ms, peak {mem_e.peak_bytes / 1e9:.2f} GB")
    print(ascii_timeline(tl_e), "\n")

    print("=== (c) Algorithm 1 refined just-in-time order (Fig. 4c) ===")
    refined = schedule.refine_order(g2, hw, early)
    tl_r = timeline.simulate(g2, hw, refined)
    mem_r = memsim.simulate(g2, refined)
    print(f"  total {tl_r.total * 1e3:.1f} ms, exposed "
          f"{tl_r.exposed_comm * 1e3:.1f} ms, peak {mem_r.peak_bytes / 1e9:.2f} GB")
    print(ascii_timeline(tl_r))
    print(f"\npeak memory: {mem_e.peak_bytes / 1e9:.2f} → "
          f"{mem_r.peak_bytes / 1e9:.2f} GB; reactive {tl_re.total * 1e3:.0f} ms "
          f"→ planned {tl_r.total * 1e3:.0f} ms")


if __name__ == "__main__":
    main()
