"""Quickstart: one `OffloadConfig`, one `HyperOffloadSession`, every
offload mechanism behind them.

    PYTHONPATH=src python examples/quickstart.py

The session is the single front door: it owns the memory pool, the async
transfer engine, and the planner, and hands out training steps and serving
engines pre-wired to them. Demonstrated end to end on CPU:

- activation offload (offload-aware remat policy) + optimizer-state host
  offload, both switched by config fields (``remat``, ``offload_opt_state``);
- KV-cache host round trips during generation (``mode="kv_offload"``) —
  numerically identical to the resident baseline;
- the merged ``session.stats()`` snapshot (pool + transfer + serve).
"""

import time

import jax
import jax.numpy as jnp

from repro.api import HyperOffloadSession, OffloadConfig
from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model


def main():
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = build_model(cfg)
    print(f"model: {cfg.name} ({cfg.n_layers} layers, d_model {cfg.d_model})")

    # one declarative config: serving mode + training memory policy
    config = OffloadConfig(mode="kv_offload", max_seq=48,
                           remat="offload", offload_opt_state=True)
    session = HyperOffloadSession(config)

    step = session.train_step(model, peak_lr=2e-3, warmup=5, total_steps=60)
    params, opt_state = session.init_train_state(
        model, jax.random.key(0), peak_lr=2e-3, warmup=5, total_steps=60)
    data = SyntheticTokens(cfg.vocab_size, seq_len=32, global_batch=8,
                           noise=0.05)

    print("training with activation + optimizer-state offload...")
    t0 = time.time()
    for i in range(60):
        params, opt_state, metrics = step(params, opt_state, data.batch(i))
        if i % 20 == 0 or i == 59:
            print(f"  step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")
    print(f"  ({time.time() - t0:.1f}s; moments live in "
          f"{jax.tree.leaves(opt_state.mu)[0].sharding.memory_kind})")

    print("generating (resident cache vs host-offloaded cache)...")
    prompt = {"tokens": data.batch(0)["tokens"][:, :16]}
    resident = session.serve_engine(model, params, offload_kv=False)
    offloaded = session.serve_engine(model, params)   # mode = kv_offload
    out_r = resident.generate(prompt, 16)
    out_o = offloaded.generate(prompt, 16)
    assert bool(jnp.all(out_r == out_o)), "offload changed results!"
    print(f"  identical generations; cache round trips: "
          f"{offloaded.stats.cache_round_trips}")
    print("  sample:", out_r[0].tolist())

    s = session.stats()
    print(f"session stats: serve={s['serve']} "
          f"pool: {s['pool']['puts']} puts / {s['pool']['gets']} gets, "
          f"{s['pool']['transfer']['issued']} async fetches "
          f"({s['pool']['transfer']['waits_overlapped']} overlapped)")
    session.close()


if __name__ == "__main__":
    main()
