"""Quickstart: train a small model with HyperOffload memory management,
then generate from it.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the three offload mechanisms end to end on CPU:
- activation offload (offload-aware remat policy),
- optimizer-state host offload,
- KV-cache host round trips during generation —
all numerically identical to the resident baselines.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model
from repro.serving.engine import ServeEngine
from repro.training.step import TrainStepConfig, init_train_state, make_train_step


def main():
    cfg = get_config("phi3-mini-3.8b").reduced()
    model = build_model(cfg)
    print(f"model: {cfg.name} ({cfg.n_layers} layers, d_model {cfg.d_model})")

    ts = TrainStepConfig(remat="offload", offload_opt_state=True,
                         peak_lr=2e-3, warmup=5, total_steps=60)
    params, opt_state = init_train_state(model, jax.random.key(0), ts=ts)
    step = make_train_step(model, ts)
    data = SyntheticTokens(cfg.vocab_size, seq_len=32, global_batch=8, noise=0.05)

    print("training with activation + optimizer-state offload...")
    t0 = time.time()
    for i in range(60):
        params, opt_state, metrics = step(params, opt_state, data.batch(i))
        if i % 20 == 0 or i == 59:
            print(f"  step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")
    print(f"  ({time.time() - t0:.1f}s; moments live in "
          f"{jax.tree.leaves(opt_state.mu)[0].sharding.memory_kind})")

    print("generating (resident cache vs host-offloaded cache)...")
    prompt = {"tokens": data.batch(0)["tokens"][:, :16]}
    resident = ServeEngine(model, params, max_seq=48)
    offloaded = ServeEngine(model, params, max_seq=48, offload_kv=True)
    out_r = resident.generate(prompt, 16)
    out_o = offloaded.generate(prompt, 16)
    assert bool(jnp.all(out_r == out_o)), "offload changed results!"
    print(f"  identical generations; cache round trips: "
          f"{offloaded.stats.cache_round_trips}")
    print("  sample:", out_r[0].tolist())


if __name__ == "__main__":
    main()
