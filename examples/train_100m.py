"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
with checkpointing, using the full training substrate.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Builds a mid-size phi3-family config (~100M params), streams the synthetic
deterministic pipeline, runs AdamW + cosine schedule with the HyperOffload
memory policy, checkpoints periodically, and verifies resume.
"""

import argparse
import dataclasses
import os
import time

import jax

from repro.api import HyperOffloadSession, OffloadConfig
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.base import LayerSpec, Segment
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model


def make_100m_config():
    base = get_config("phi3-mini-3.8b")
    return dataclasses.replace(
        base,
        name="phi3-100m",
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=1536,
        vocab_size=32064,
        segments=(Segment(pattern=(LayerSpec(mixer="attn", ffn="swiglu"),),
                          repeats=10),),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = make_100m_config()
    model = build_model(cfg)
    session = HyperOffloadSession(OffloadConfig(remat="offload"))
    ts = session.train_config(peak_lr=6e-4, warmup=args.steps // 10,
                              total_steps=args.steps)
    params, opt_state = session.init_train_state(model, jax.random.key(0),
                                                 ts=ts)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n / 1e6:.1f}M params, {args.steps} steps @ "
          f"batch {args.batch} × seq {args.seq_len}")

    step = session.train_step(model, ts)
    data = SyntheticTokens(cfg.vocab_size, seq_len=args.seq_len,
                           global_batch=args.batch, noise=0.05)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        params, opt_state, metrics = step(params, opt_state, data.batch(i))
        losses.append(float(metrics["loss"]))
        if i % 25 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq_len * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"({tok_s:.0f} tok/s)")
        if (i + 1) % 100 == 0:
            save_checkpoint(os.path.join(args.ckpt_dir, "latest.npz"),
                            params, i + 1)
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} "
          f"(uniform floor ≈ {jax.numpy.log(cfg.vocab_size):.2f})")

    restored, at = load_checkpoint(os.path.join(args.ckpt_dir, "latest.npz"),
                                   params)
    print(f"checkpoint resume verified at step {at}")
    session.close()


if __name__ == "__main__":
    main()
