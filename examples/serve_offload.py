"""Serving with a paged, pool-resident KV cache and sparse block selection
(the paper's §5.2 / DeepSeek+NSA case study, on a real small model).

    PYTHONPATH=src python examples/serve_offload.py [--continuous]

A GQA attention layer decodes against a PagedKVCache whose full pages live
in pinned-host (remote pool) memory. Each step selects the top-k most
relevant pages (mean-key summaries), prefetches only those, and attends
over [selected pages ++ device tail]. Selecting all pages is numerically
identical to dense attention; the sparse setting trades a bounded error
for fetching a fraction of the cache — the paper's NSA trade-off.

``--continuous`` instead demos the request-level continuous-batching
scheduler (``repro.sched``): mixed-length Poisson arrivals served on a
small slot pool with plan-driven KV prefetch and host-tier eviction of
cold sequences' pages. Adding ``--slo`` annotates the trace with mixed
interactive/batch priority classes, overloads the arrival rate, and
turns on SLO-aware scheduling (deadline-first admission, preemption,
early shedding) — the demo ends with a per-class attainment summary.

``--trace-out PATH`` turns the session's telemetry on for either demo:
the overlap summary (hidden vs exposed transfer time, straight from the
trace) prints at the end and the Chrome trace-event JSON lands at PATH
(open it at https://ui.perfetto.dev).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import HyperOffloadSession, OffloadConfig
from repro.api.config import TelemetryConfig
from repro.kernels.ref import decode_attention_ref


def _telemetry(trace_out):
    return TelemetryConfig(enable=trace_out is not None,
                           trace_path=trace_out)


def _print_overlap(session, trace_out):
    """Overlap summary from the trace ring (tracing on only)."""
    ov = session.overlap()
    if ov is None:
        return
    hf = ov["hidden_fraction"]
    print(f"overlap: {ov['transfers']} transfers, "
          f"{ov['hidden_s'] * 1e3:.1f} ms hidden / "
          f"{ov['exposed_s'] * 1e3:.1f} ms exposed "
          f"(hidden fraction "
          f"{'n/a' if hf is None else format(hf, '.0%')}); "
          f"trace → {trace_out}")


def main(trace_out=None):
    b, hq, hkv, d = 2, 8, 4, 64
    page, ctx = 32, 512
    scale = d ** -0.5
    ks = jax.random.split(jax.random.key(0), 4)

    n_pages = -(-(ctx + 64) // page)
    page_nbytes = b * page * hkv * d * 4
    # host tier sized to exactly hold every K and V page (overflow would
    # spill to the remote tier) — tier topology is config, not a call site
    session = HyperOffloadSession(OffloadConfig(
        mode="paged", max_seq=ctx + 64, page_size=page,
        host_capacity=2 * n_pages * page_nbytes,
        telemetry=_telemetry(trace_out)))
    cache = session.paged_kv(batch=b, n_kv_heads=hkv, head_dim=d)
    k_ctx = jax.random.normal(ks[0], (b, ctx, hkv, d))
    v_ctx = jax.random.normal(ks[1], (b, ctx, hkv, d))
    cache.prefill(k_ctx, v_ctx)
    print(f"prefilled {ctx} tokens → {cache.full_pages} pool pages "
          f"(host-resident) + {cache.tail_len} tail tokens")

    q = jax.random.normal(ks[2], (b, hq, d))

    # dense oracle
    kd = k_ctx.transpose(0, 2, 1, 3)
    ref = decode_attention_ref(q, kd, v_ctx.transpose(0, 2, 1, 3),
                               jnp.int32(ctx - 1), scale=scale)

    t0 = time.time()
    out_all = cache.attend(q, scale=scale, top_k_pages=None)
    t_all = time.time() - t0
    err_all = float(jnp.max(jnp.abs(out_all - ref)))

    for k in (8, 4, 2):
        cache.fetches = 0
        t0 = time.time()
        out_k = cache.attend(q, scale=scale, top_k_pages=k)
        dt = time.time() - t0
        err = float(jnp.max(jnp.abs(out_k - ref)))
        print(f"top-{k:2d} pages: fetched {cache.fetches}/{cache.full_pages} "
              f"pages, err vs dense {err:.3e}, {dt * 1e3:.1f} ms")
    print(f"all pages: err {err_all:.3e} (exact), {t_all * 1e3:.1f} ms")

    # fused paged decode: pages install once into the device page buffer,
    # the page table indexes them in place — no per-step gather/concat
    out_f = cache.attend_fused(q, scale=scale)     # warm: installs pages
    t0 = time.time()
    out_f = cache.attend_fused(q, scale=scale)
    jax.block_until_ready(out_f)
    t_fused = time.time() - t0
    print(f"fused decode: bitwise match {bool(jnp.all(out_f == out_all))}, "
          f"{t_fused * 1e3:.1f} ms ({cache.buffer_hits} buffer hits / "
          f"{cache.buffer_misses} installs)")

    # decode loop with async prefetch: select on the post-append state (so a
    # page flushed this step is a candidate), issue all page fetches at once
    # through the transfer engine, and wait only inside attend — the fetches
    # overlap each other and the selection/summary work
    flushes0 = cache.flushes
    for t in range(64):
        cache.append(jax.random.normal(jax.random.fold_in(ks[3], t), (b, hkv, d)),
                     jax.random.normal(jax.random.fold_in(ks[3], 1000 + t), (b, hkv, d)))
        inflight = cache.prefetch_pages(cache.select_pages(q, top_k=4))
        _ = cache.attend(q, scale=scale, prefetched=inflight)
    print(f"decoded 64 tokens; {cache.flushes - flushes0} pages flushed to "
          f"the pool during decode; cache length {cache.length}")

    # pool-manager traffic/occupancy: what the runtime actually moved
    s = cache.pool_stats()
    host, xfer = s["tier/host"], s["transfer"]
    print(f"pool stats: {s['puts']} puts / {s['gets']} gets, "
          f"{s['bytes_stored'] / 1e6:.2f} MB stored, "
          f"{s['bytes_fetched'] / 1e6:.2f} MB fetched, "
          f"host tier {host['used'] / 1e6:.2f}/{(host['capacity'] or 0) / 1e6:.2f} MB "
          f"({host['entries']} pages, backend {host['backend']})")
    print(f"transfer engine: {xfer['issued']} async fetches issued, "
          f"{xfer['waits_overlapped']} fully overlapped, "
          f"{xfer['waits_blocked']} blocked ({xfer['blocked_s'] * 1e3:.1f} ms exposed)")
    _print_overlap(session, trace_out)
    session.close()


def main_continuous(trace_out=None, slo=False):
    """Continuous-batching scheduler demo: mixed traffic, pool-parked KV."""
    from repro.configs import REGISTRY
    from repro.models.model import build_model
    from repro.offload.kvcache import worst_case_page_bytes
    from repro.sched import poisson_trace
    from repro.slo import SLOConfig, attainment_summary

    cfg = REGISTRY["phi3-mini-3.8b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    max_batch, max_seq = 3, 48
    row = worst_case_page_bytes(model.cache_specs(1, max_seq, jnp.float32))
    # device tier ≈ 1.5 cache rows: cold sequences' pages spill to host
    session = HyperOffloadSession(OffloadConfig(
        mode="kv_offload", max_batch=max_batch, max_seq=max_seq,
        prefill_budget=2,
        device_capacity=int(1.5 * row),
        host_capacity=2 * max_batch * row,
        telemetry=_telemetry(trace_out),
        slo=SLOConfig(enable=slo)))
    sched = session.scheduler(model, params)
    # --slo: overload the arrival rate and mix interactive (TTFT-deadline)
    # with batch (throughput-only) requests, so the deadline-first policy
    # has something to prioritize
    rate = 2.4 if slo else 0.8
    trace = poisson_trace(10, rate=rate, vocab_size=cfg.vocab_size,
                          prompt_lens=(4, 16), new_tokens=(2, 12),
                          prompt_quantum=4,
                          interactive_fraction=0.4 if slo else None,
                          seed=0)
    t0 = time.time()
    out = sched.run(trace)
    dt = time.time() - t0
    tokens = sum(len(v) for v in out.values())
    st = sched.stats
    print(f"continuous scheduler: {len(out)} requests, {tokens} tokens in "
          f"{st.steps} steps ({dt:.2f}s wall) — {st.joins} joins / "
          f"{st.retires} retires, {sched.admission.blocked} admission blocks")
    print(f"pages: {st.pages_parked} parked, {st.cold_spills} cold spills "
          f"to lower tiers")
    pf = sched.prefetch_stats()
    xfer = sched.pool_stats()["transfer"]
    print(f"plan-driven prefetch: {pf['fetches_issued']} fetches over "
          f"{pf['layers_planned']} planned layers, mean plan lead "
          f"{pf['mean_plan_lead']:.1f} slots; {xfer['waits_overlapped']} "
          f"waits overlapped / {xfer['waits_blocked']} blocked")
    lat = sorted(s.t_done - s.request.arrival for s in sched.finished.values())
    print(f"latency (steps): p50 {lat[len(lat) // 2]:.1f}, max {lat[-1]:.1f}")
    if slo:
        att = attainment_summary(sched.finished.values())
        print(f"slo: {att['met_tokens']}/{att['tokens']} tokens within "
              f"deadline ({st.preemptions} preemptions, {st.resumes} "
              f"resumes, {st.shed} shed)")
        for cls, c in sorted(att["classes"].items()):
            tta = c["ttft_attainment"]
            print(f"  {cls}: {c['met_tokens']}/{c['tokens']} tokens met "
                  f"({c['requests']} requests, {c['shed']} shed), "
                  f"ttft attainment "
                  f"{'n/a' if tta is None else format(tta, '.0%')}")
    _print_overlap(session, trace_out)
    session.close()   # closes the scheduler and the session-owned pool


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--continuous", action="store_true",
                    help="run the continuous-batching scheduler demo")
    ap.add_argument("--slo", action="store_true",
                    help="with --continuous: overloaded mixed-class trace "
                         "under SLO-aware scheduling + attainment summary")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry; write the Chrome trace here")
    args = ap.parse_args()
    if args.continuous:
        main_continuous(args.trace_out, slo=args.slo)
    elif args.slo:
        ap.error("--slo requires --continuous")
    else:
        main(args.trace_out)
