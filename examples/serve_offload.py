"""Serving with a paged, pool-resident KV cache and sparse block selection
(the paper's §5.2 / DeepSeek+NSA case study, on a real small model).

    PYTHONPATH=src python examples/serve_offload.py

A GQA attention layer decodes against a PagedKVCache whose full pages live
in pinned-host (remote pool) memory. Each step selects the top-k most
relevant pages (mean-key summaries), prefetches only those, and attends
over [selected pages ++ device tail]. Selecting all pages is numerically
identical to dense attention; the sparse setting trades a bounded error
for fetching a fraction of the cache — the paper's NSA trade-off.
"""

import time

import jax
import jax.numpy as jnp

from repro.offload.kvcache import PagedKVCache
from repro.kernels.ref import decode_attention_ref


def main():
    b, hq, hkv, d = 2, 8, 4, 64
    page, ctx = 32, 512
    scale = d ** -0.5
    ks = jax.random.split(jax.random.key(0), 4)

    cache = PagedKVCache.create(batch=b, max_seq=ctx + 64, page_size=page,
                                n_kv_heads=hkv, head_dim=d)
    k_ctx = jax.random.normal(ks[0], (b, ctx, hkv, d))
    v_ctx = jax.random.normal(ks[1], (b, ctx, hkv, d))
    cache.prefill(k_ctx, v_ctx)
    print(f"prefilled {ctx} tokens → {cache.full_pages} pool pages "
          f"(host-resident) + {cache.tail_len} tail tokens")

    q = jax.random.normal(ks[2], (b, hq, d))

    # dense oracle
    kd = k_ctx.transpose(0, 2, 1, 3)
    ref = decode_attention_ref(q, kd, v_ctx.transpose(0, 2, 1, 3),
                               jnp.int32(ctx - 1), scale=scale)

    t0 = time.time()
    out_all = cache.attend(q, scale=scale, top_k_pages=None)
    t_all = time.time() - t0
    err_all = float(jnp.max(jnp.abs(out_all - ref)))

    for k in (8, 4, 2):
        cache.fetches = 0
        t0 = time.time()
        out_k = cache.attend(q, scale=scale, top_k_pages=k)
        dt = time.time() - t0
        err = float(jnp.max(jnp.abs(out_k - ref)))
        print(f"top-{k:2d} pages: fetched {cache.fetches}/{cache.full_pages} "
              f"pages, err vs dense {err:.3e}, {dt * 1e3:.1f} ms")
    print(f"all pages: err {err_all:.3e} (exact), {t_all * 1e3:.1f} ms")

    # decode loop with async prefetch: select on the post-append state (so a
    # page flushed this step is a candidate), issue all page fetches at once
    # through the transfer engine, and wait only inside attend — the fetches
    # overlap each other and the selection/summary work
    flushes0 = cache.flushes
    for t in range(64):
        cache.append(jax.random.normal(jax.random.fold_in(ks[3], t), (b, hkv, d)),
                     jax.random.normal(jax.random.fold_in(ks[3], 1000 + t), (b, hkv, d)))
        inflight = cache.prefetch_pages(cache.select_pages(q, top_k=4))
        _ = cache.attend(q, scale=scale, prefetched=inflight)
    print(f"decoded 64 tokens; {cache.flushes - flushes0} pages flushed to "
          f"the pool during decode; cache length {cache.length}")

    # pool-manager traffic/occupancy: what the runtime actually moved
    s = cache.pool_stats()
    host, xfer = s["tier/host"], s["transfer"]
    print(f"pool stats: {s['puts']} puts / {s['gets']} gets, "
          f"{s['bytes_stored'] / 1e6:.2f} MB stored, "
          f"{s['bytes_fetched'] / 1e6:.2f} MB fetched, "
          f"host tier {host['used'] / 1e6:.2f}/{(host['capacity'] or 0) / 1e6:.2f} MB "
          f"({host['entries']} pages, backend {host['backend']})")
    print(f"transfer engine: {xfer['issued']} async fetches issued, "
          f"{xfer['waits_overlapped']} fully overlapped, "
          f"{xfer['waits_blocked']} blocked ({xfer['blocked_s'] * 1e3:.1f} ms exposed)")


if __name__ == "__main__":
    main()
