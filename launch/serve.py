"""Serving launcher: one continuous-batching serve over a Poisson trace,
with the telemetry front door exposed as flags.

    PYTHONPATH=src python launch/serve.py [--mode continuous|kv_offload]
        [--trace-out TRACE.json] [--stats-json STATS.json]

``--trace-out`` enables the session's telemetry block
(``OffloadConfig.telemetry``) and writes the Chrome trace-event /
Perfetto JSON file there on session close — open it at
https://ui.perfetto.dev. ``--stats-json`` writes the merged
``session.stats()`` snapshot (pool/transfer/sched counters, plus the
latency histograms and trace-ring state when tracing is on). With
neither flag the launcher serves exactly as before — telemetry stays
disabled and no tracer is ever constructed.

SLO flags: ``--interactive-fraction F`` annotates the trace with mixed
interactive/batch priority classes and TTFT deadlines; ``--slo`` turns
on the SLO-aware scheduler (deadline-first admission, preemption, early
shedding — ``OffloadConfig.slo``); ``--overload X`` multiplies the
arrival rate by X to push the trace past capacity. With any of them the
launcher prints a per-class SLO attainment summary after the run.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.api import HyperOffloadSession, OffloadConfig
from repro.api.config import TelemetryConfig
from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.offload.kvcache import worst_case_page_bytes
from repro.sched import poisson_trace
from repro.slo import SLOConfig, attainment_summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--mode", choices=("continuous", "kv_offload"),
                    default="kv_offload")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.8,
                    help="Poisson arrivals per scheduler step")
    ap.add_argument("--max-batch", type=int, default=3)
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry and write the Chrome trace here")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="write the merged session.stats() snapshot here")
    ap.add_argument("--slo", action="store_true",
                    help="SLO-aware scheduling: deadline-first admission, "
                         "preemption, early shedding")
    ap.add_argument("--overload", type=float, default=None, metavar="X",
                    help="multiply --rate by X (drive the trace past "
                         "capacity)")
    ap.add_argument("--interactive-fraction", type=float, default=None,
                    metavar="F",
                    help="annotate the trace: F of requests interactive "
                         "(TTFT deadline), rest batch")
    args = ap.parse_args()
    if args.slo and args.interactive_fraction is None:
        args.interactive_fraction = 0.35   # --slo alone still demos SLOs

    cfg = REGISTRY[args.arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    kwargs = dict(mode=args.mode, max_batch=args.max_batch,
                  max_seq=args.max_seq, prefill_budget=2)
    if args.mode == "kv_offload":
        # device tier ≈ half the running batch: cold pages spill to host
        # and prefetch back — the traffic the trace is interesting for
        row = worst_case_page_bytes(
            model.cache_specs(1, args.max_seq, jnp.float32))
        kwargs.update(device_capacity=max(1, args.max_batch // 2) * row,
                      host_capacity=2 * args.max_batch * row)
    if args.trace_out is not None:
        kwargs["telemetry"] = TelemetryConfig(enable=True,
                                              trace_path=args.trace_out)
    if args.slo:
        kwargs["slo"] = SLOConfig(enable=True)

    session = HyperOffloadSession(OffloadConfig(**kwargs))
    sched = session.scheduler(model, params)
    rate = args.rate * (args.overload or 1.0)
    trace = poisson_trace(args.requests, rate=rate,
                          vocab_size=cfg.vocab_size, prompt_lens=(4, 16),
                          new_tokens=(2, 12), prompt_quantum=4,
                          interactive_fraction=args.interactive_fraction,
                          seed=args.seed)
    t0 = time.time()
    out = sched.run(trace)
    wall = time.time() - t0
    tokens = sum(len(v) for v in out.values())
    print(f"serve,{args.mode},requests:{len(out)},tokens:{tokens},"
          f"steps:{sched.stats.steps},wall_s:{wall:.2f}")

    if args.slo or args.interactive_fraction is not None:
        att = attainment_summary(sched.finished.values())
        st = sched.stats
        print(f"serve,slo,goodput_tokens:{att['met_tokens']},"
              f"goodput_tok/step:"
              f"{att['met_tokens'] / max(sched.now, 1e-9):.2f},"
              f"preemptions:{st.preemptions},resumes:{st.resumes},"
              f"shed:{st.shed}")
        for cls, c in sorted(att["classes"].items()):
            tta = c["ttft_attainment"]
            print(f"serve,slo_class,{cls},requests:{c['requests']},"
                  f"met_tokens:{c['met_tokens']}/{c['tokens']},"
                  f"shed:{c['shed']},ttft_attainment:"
                  f"{'n/a' if tta is None else format(tta, '.2f')}")

    if args.trace_out is not None:
        ov = session.overlap()
        hf = ov["hidden_fraction"]
        print(f"serve,overlap,transfers:{ov['transfers']},"
              f"hidden_s:{ov['hidden_s']:.4f},"
              f"exposed_s:{ov['exposed_s']:.4f},hidden_fraction:"
              f"{'n/a' if hf is None else format(hf, '.2f')}")
    if args.stats_json is not None:
        with open(args.stats_json, "w") as f:
            json.dump(session.stats(), f, indent=2, sort_keys=True,
                      default=str)
        print(f"serve,stats,{args.stats_json}")
    session.close()   # exports the trace to --trace-out (telemetry.trace_path)
    if args.trace_out is not None:
        print(f"serve,trace,{args.trace_out}")


if __name__ == "__main__":
    main()
