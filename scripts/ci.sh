#!/usr/bin/env bash
# Tier-1 CI gate: install requirements (if anything is missing) and run the
# full test suite. Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import jax, numpy, pytest" 2>/dev/null; then
    python -m pip install --quiet -r requirements.txt
fi

# full suite including slow-marked end-to-end cases (pytest.ini deselects
# them by default so the tier-1 gate stays fast)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q -m "slow or not slow" "$@"

# public-API smoke: the quickstart exercises the OffloadConfig /
# HyperOffloadSession front door end to end (train + serve + stats)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python examples/quickstart.py

# default-config dump: any drift in the public config surface (new field,
# changed default) shows up as a CONFIG_default.json diff in review
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.api --print-config > CONFIG_default.json

# serving perf smoke: continuous vs static batching on a mixed-length
# Poisson trace; summary accumulates in BENCH_serving.json
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/serve_continuous.py --smoke --out BENCH_serving.json

# the kv_offload smoke must REPORT its latency hiding: the overlap
# section (trace-derived, counter-validated) with a non-null hidden
# fraction is part of the benchmark contract, not an optional extra
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import json
ov = json.load(open("BENCH_serving.json"))["kv_offload"]["overlap"]
assert ov["hidden_fraction"] is not None, \
    "kv_offload smoke reported no hidden_fraction (no transfer time traced)"
print(f"ci,overlap,hidden_fraction:{ov['hidden_fraction']:.2f}")
EOF

# calibration gate: closing the planning loop on measured bandwidth must
# hide at least as much transfer time as static planning on the same
# trace — the modeled tier's latency is enforced, so this is the paper's
# overlap claim as a hard assert, not a flaky perf check
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import json
cal = json.load(open("BENCH_serving.json"))["calibration"]
hs = cal["static"]["hidden_fraction"]
hc = cal["calibrated"]["hidden_fraction"]
assert hs is not None and hc is not None, "calibration arms traced nothing"
assert hc >= hs, \
    f"calibrated hidden_fraction {hc:.3f} < static {hs:.3f}"
print(f"ci,calibration,hidden_fraction:{hs:.2f}->{hc:.2f},"
      f"workers:{cal['static']['workers']}->{cal['calibrated']['workers']}")
EOF

# decode-kernel gate: the fused paged-decode path must beat the legacy
# per-step gather/concat on decode tok/s with token-identical output
# (codec "none"), and int8 KV pages must at least halve the on-wire
# bytes per pool fetch — the PR's two headline claims as hard asserts
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import json
dk = json.load(open("BENCH_serving.json"))["decode_kernel"]
g = dk["gather"]["tokens_per_s"]
f = dk["fused"]["tokens_per_s"]
assert f > g, f"fused decode {f:.1f} tok/s <= gather {g:.1f}"
assert dk["tokens_match_gather"], "fused decode diverged from gather output"
br = dk["codec"]["byte_reduction"]
assert br >= 2.0, f"int8 pages cut wire bytes only {br:.2f}x (< 2x)"
print(f"ci,decode_kernel,tok/s:{g:.1f}->{f:.1f},"
      f"speedup:{dk['decode_speedup']:.2f},byte_reduction:{br:.2f}")
EOF

# SLO gate: at 3x overload the SLO-aware scheduler must beat FIFO on
# goodput (deadline-met tokens per virtual step) AND on interactive TTFT
# attainment — both on the deterministic virtual clock, so this is a
# hard assert, not a flaky perf check
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import json
o = json.load(open("BENCH_serving.json"))["overload"]["3x"]
fifo, slo = o["fifo"], o["slo"]
gf = fifo["goodput_tokens_per_step"]
gs = slo["goodput_tokens_per_step"]
assert gs > gf, f"SLO goodput {gs:.3f} <= FIFO {gf:.3f} at 3x overload"
tf = fifo["attainment"]["classes"]["interactive"]["ttft_attainment"]
ts = slo["attainment"]["classes"]["interactive"]["ttft_attainment"]
assert ts > tf, f"SLO interactive TTFT attainment {ts:.2f} <= FIFO {tf:.2f}"
print(f"ci,slo_overload_3x,goodput:{gf:.2f}->{gs:.2f},"
      f"ttft_attainment:{tf:.2f}->{ts:.2f}")
EOF

# traced smoke serve: capture one Chrome trace through the launcher's
# telemetry flags and validate it against the repro.obs schema checker
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python launch/serve.py --requests 4 --trace-out TRACE_smoke.json
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.obs.check TRACE_smoke.json
rm -f TRACE_smoke.json
