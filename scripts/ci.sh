#!/usr/bin/env bash
# Tier-1 CI gate: install requirements (if anything is missing) and run the
# full test suite. Usage: scripts/ci.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import jax, numpy, pytest" 2>/dev/null; then
    python -m pip install --quiet -r requirements.txt
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
