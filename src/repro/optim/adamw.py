"""AdamW, from scratch (no optax dependency).

Moments are kept in f32 regardless of parameter dtype. The state is a plain
pytree, so HyperOffload's optimizer-state offload (offload.optstate) can
park it in host memory between steps with a single ``device_put``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # scalar int32
    mu: Any               # first moments (f32 pytree)
    nu: Any               # second moments (f32 pytree)


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state)."""
    # global-norm clip in f32
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g32)))
    clip = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
    g32 = jax.tree.map(lambda g: g * clip, g32)

    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, g32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
