"""Train-step builder: loss → grads → AdamW, with selectable memory policy.

Memory policies map to the paper's training case study (§5.1):

- ``remat="none"``      — keep all activations (memory-hungry baseline)
- ``remat="full"``      — recompute everything (the paper's baseline
                          memory-saving technique; ~+1 forward of FLOPs)
- ``remat="offload"``   — HyperOffload: park tagged activations
                          ("resid"/"attn_out"/"mlp_out") in pinned_host
                          instead of recomputing or keeping them in HBM
- ``offload_opt_state`` — park AdamW moments in host memory between steps
                          (§5.1 case 2); the step fetches them on entry
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.offload.policies import OFFLOADABLE_NAMES, offload_remat_policy, remat_policy
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    remat: str = "none"              # none | full | offload
    offload_opt_state: bool = False
    # memory kind for parked moments (None = probe the platform); comes
    # from OffloadConfig.host_memory_kind when built through the session
    host_kind: Optional[str] = None
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # gradient accumulation: split the global batch into N microbatches
    # scanned sequentially — activation memory scales with batch/N while the
    # optimizer sees the full-batch gradient (composes with offload remat)
    grad_accum: int = 1


def _policy(ts: TrainStepConfig):
    if ts.remat == "none":
        return None
    if ts.remat == "full":
        return remat_policy("nothing")
    if ts.remat == "offload":
        return offload_remat_policy(OFFLOADABLE_NAMES)
    raise ValueError(ts.remat)


def make_train_step(model: Model, ts: TrainStepConfig = TrainStepConfig(),
                    jit: bool = True) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    policy = _policy(ts)

    def loss_fn(params, batch):
        return model.loss(params, batch, remat_policy=policy)

    def grad_accum_fn(params, batch):
        """Mean loss/grads over ts.grad_accum sequential microbatches."""
        n = ts.grad_accum
        micro = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, grad_acc, grads)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grad_sum), _ = jax.lax.scan(body, zero, micro)
        inv = 1.0 / n
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]):
        if ts.grad_accum > 1:
            loss, grads = grad_accum_fn(params, batch)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = cosine_schedule(opt_state.step + 1, peak_lr=ts.peak_lr,
                             warmup=ts.warmup, total=ts.total_steps)
        if ts.offload_opt_state:
            # Prefetch the moments from the pool for the update...
            from repro.offload.optstate import fetch_in_jit
            opt_state = AdamWState(step=opt_state.step,
                                   mu=fetch_in_jit(opt_state.mu),
                                   nu=fetch_in_jit(opt_state.nu))
        new_params, new_state = adamw_update(
            grads, opt_state, params, lr,
            b1=ts.b1, b2=ts.b2, weight_decay=ts.weight_decay,
            grad_clip=ts.grad_clip)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gn, "lr": lr}
        return new_params, new_state, metrics

    if jit:
        step = jax.jit(step, donate_argnums=(0, 1))

    if not ts.offload_opt_state:
        return step

    # Store the updated moments back to the pool after each step. XLA:CPU
    # cannot place jit *outputs* in host memory (annotate_device_placement is
    # TPU/GPU-only), so the Store happens as an async device_put immediately
    # after dispatch — on TPU this is the same DMA the in-jit path would
    # emit, overlapped with the next step's forward.
    from repro.offload.optstate import host_offload_state

    def step_with_park(params, opt_state: AdamWState, batch):
        new_params, new_state, metrics = step(params, opt_state, batch)
        new_state = AdamWState(step=new_state.step,
                               mu=host_offload_state(new_state.mu, ts.host_kind),
                               nu=host_offload_state(new_state.nu, ts.host_kind))
        return new_params, new_state, metrics

    return step_with_park


def init_train_state(model: Model, key, dtype=jnp.float32,
                     ts: TrainStepConfig = TrainStepConfig()) -> Tuple[Any, AdamWState]:
    params = model.init(key, dtype)
    opt_state = adamw_init(params)
    if ts.offload_opt_state:
        from repro.offload.optstate import host_offload_state
        opt_state = AdamWState(step=opt_state.step,
                               mu=host_offload_state(opt_state.mu, ts.host_kind),
                               nu=host_offload_state(opt_state.nu, ts.host_kind))
    return params, opt_state
