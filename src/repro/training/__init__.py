from repro.training.step import TrainStepConfig, make_train_step

__all__ = ["TrainStepConfig", "make_train_step"]
