"""Unified telemetry subsystem: tracing, metrics, overlap analysis.

- ``trace``   — `Tracer`: structured spans/instants in a bounded ring,
  exported as Chrome trace-event / Perfetto JSON; `NullTracer` makes
  disabled telemetry a no-op (``NULL_TRACER`` is the shared instance);
- ``metrics`` — `MetricsRegistry`: counters, gauges, fixed-bucket
  histograms, plus named collectors that re-home the existing subsystem
  stats snapshots; Prometheus-style text exposition;
- ``overlap`` — `OverlapAnalyzer`: post-processes the trace into
  hidden-vs-exposed transfer time per tier pair and per scheduler step —
  the direct measurement of the paper's latency-hiding claim — and
  cross-validates it against `TransferStats`;
- ``check``   — trace-file schema checker (`python -m repro.obs.check`),
  the CI gate on exported traces.

The session front door (`repro.api`) owns ONE tracer and ONE registry per
session (``OffloadConfig.telemetry``) and hands them to every subsystem it
constructs; subsystems accept a ``tracer=None`` kwarg and stay silent
without one.
"""

from repro.obs.metrics import (
    Counter, Gauge, Histogram, MetricsRegistry, STEP_BUCKETS,
)
from repro.obs.overlap import OverlapAnalyzer
from repro.obs.trace import NULL_TRACER, NullTracer, TraceEvent, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "STEP_BUCKETS",
    "OverlapAnalyzer",
    "NULL_TRACER", "NullTracer", "TraceEvent", "Tracer",
]
