"""Metrics registry: counters, gauges, fixed-bucket histograms, collectors.

`MetricsRegistry` is the one aggregation point the session's observability
surface hangs off. Two kinds of metric live here:

- **owned instruments** — `Counter` / `Gauge` / `Histogram` objects created
  through the registry (the scheduler's per-request TTFT / queue-wait /
  per-output-token histograms);
- **collectors** — named callables returning a stats mapping, registered by
  the session for every subsystem snapshot that already exists
  (``PoolStats``/``TransferStats``/``SchedStats``/``ServeStats``/prefix
  counters). ``collect()`` re-homes those legacy snapshots onto the
  registry without forcing every subsystem to hold registry handles.

``render_prometheus()`` emits a Prometheus-style text exposition of both:
owned instruments with ``# TYPE`` headers (histograms in the cumulative
``_bucket{le=...}`` form), collector output flattened to
``name_path value`` samples (non-numeric leaves skipped).
"""

from __future__ import annotations

import bisect
import re
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]

#: default histogram buckets for scheduler-step latencies (virtual steps)
STEP_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n


class Gauge:
    """Set-to-current-value instrument."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram (cumulative on export, per-bucket inside).
    ``buckets`` are upper bounds; observations above the last bound land
    in the implicit +Inf bucket."""

    __slots__ = ("name", "help", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = "") -> None:
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("buckets must be a non-empty ascending sequence")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # [+Inf] last
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def snapshot(self) -> Dict[str, Any]:
        cum, cumulative = 0, {}
        for b, c in zip(self.buckets, self.counts):
            cum += c
            cumulative[b] = cum
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count if self.count else 0.0,
                "buckets": cumulative}


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


def _flatten(prefix: str, obj: Any, out: List[Tuple[str, float]]) -> None:
    if isinstance(obj, Mapping):
        for k, v in obj.items():
            _flatten(f"{prefix}_{k}" if prefix else str(k), v, out)
    elif isinstance(obj, bool) or obj is None:
        return
    elif isinstance(obj, (int, float)):
        out.append((prefix, float(obj)))


class MetricsRegistry:
    """Counters/gauges/histograms plus legacy-snapshot collectors (see
    module doc). Instrument getters are idempotent: asking for an existing
    name returns the existing instrument (a histogram re-request must name
    the same buckets)."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._collectors: Dict[str, Callable[[], Any]] = {}

    # -- owned instruments ---------------------------------------------
    def counter(self, name: str, help: str = "") -> Counter:
        if name not in self._counters:
            self._counters[name] = Counter(name, help)
        return self._counters[name]

    def gauge(self, name: str, help: str = "") -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge(name, help)
        return self._gauges[name]

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "") -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, buckets, help)
        elif h.buckets != tuple(float(b) for b in buckets):
            raise ValueError(
                f"histogram {name!r} already registered with buckets "
                f"{h.buckets}")
        return h

    # -- collectors (legacy snapshot re-homing) -------------------------
    def register_collector(self, name: str, fn: Callable[[], Any]) -> None:
        """Register ``fn() -> stats mapping`` under ``name``; ``collect``
        and the Prometheus exposition call it lazily. Re-registering a
        name replaces it."""
        self._collectors[name] = fn

    def collect(self) -> Dict[str, Any]:
        """Every collector's current snapshot, in registration order —
        the session's ``stats()`` body."""
        return {name: fn() for name, fn in self._collectors.items()}

    # -- export ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Owned instruments only (collectors are read via ``collect``)."""
        out: Dict[str, Any] = {}
        if self._counters:
            out["counters"] = {n: c.value for n, c in self._counters.items()}
        if self._gauges:
            out["gauges"] = {n: g.value for n, g in self._gauges.items()}
        if self._histograms:
            out["histograms"] = {n: h.snapshot()
                                 for n, h in self._histograms.items()}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition: owned instruments (typed) plus
        flattened collector samples (untyped gauges)."""
        lines: List[str] = []
        for c in self._counters.values():
            n = _prom_name(c.name)
            if c.help:
                lines.append(f"# HELP {n} {c.help}")
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {c.value:g}")
        for g in self._gauges.values():
            n = _prom_name(g.name)
            if g.help:
                lines.append(f"# HELP {n} {g.help}")
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {g.value:g}")
        for h in self._histograms.values():
            n = _prom_name(h.name)
            if h.help:
                lines.append(f"# HELP {n} {h.help}")
            lines.append(f"# TYPE {n} histogram")
            cum = 0
            for b, c in zip(h.buckets, h.counts):
                cum += c
                lines.append(f'{n}_bucket{{le="{b:g}"}} {cum}')
            lines.append(f'{n}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{n}_sum {h.sum:g}")
            lines.append(f"{n}_count {h.count}")
        for name, fn in self._collectors.items():
            samples: List[Tuple[str, float]] = []
            _flatten(_prom_name(name), fn(), samples)
            for sample_name, value in samples:
                lines.append(f"{_prom_name(sample_name)} {value:g}")
        return "\n".join(lines) + "\n"
