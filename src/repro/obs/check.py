"""Trace-file schema checker (CI gate for the telemetry subsystem).

Validates that an exported trace is (a) well-formed Chrome trace-event
JSON that Perfetto will open, and (b) consistent with the repo's span
schema: every complete span has a non-negative duration (end >= start),
and every transfer handle's events are ordered execution-start <=
complete <= wait-resolution. (The transfer span covers execution only —
queue time shows up as ``transfer.backpressure`` — so a blocked wait may
legitimately *start* before its transfer span does; wait-start ordering
is only an invariant for overlapped waits.) Run from CI as

    PYTHONPATH=src python -m repro.obs.check TRACE.json

Exit status 0 = valid; 1 = violations (printed one per line).
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from repro.obs.overlap import (
    SCHED_CAT, STEP_SPAN, TRANSFER_CAT, TRANSFER_SPAN, WAIT_SPAN,
)

__all__ = ["validate_events", "validate_file"]

_PHASES = {"X", "i", "M"}
#: float slop for cross-thread perf_counter comparisons (microseconds)
_EPS_US = 50.0


def validate_events(obj: Any) -> List[str]:
    """Validate a parsed Chrome trace object. Returns violation messages
    (empty = valid)."""
    errors: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' array"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be an array"]
    transfers: Dict[int, Dict[str, float]] = {}
    waits: Dict[int, Dict[str, Any]] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: event must be an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: ph {ph!r} not in {sorted(_PHASES)}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing/empty 'name'")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: 'ts' must be a number")
            continue
        if not isinstance(ev.get("pid"), int) or not isinstance(
                ev.get("tid"), int):
            errors.append(f"{where}: 'pid'/'tid' must be integers")
        if ph == "X":
            n_spans += 1
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)):
                errors.append(f"{where}: complete span missing 'dur'")
                continue
            if dur < 0:
                errors.append(f"{where}: span end < start (dur {dur})")
                continue
            args = ev.get("args", {})
            if (ev.get("cat") == TRANSFER_CAT
                    and ev["name"] in (TRANSFER_SPAN, WAIT_SPAN)):
                if "seq" not in args:
                    errors.append(f"{where}: {ev['name']} span missing "
                                  "args.seq")
                    continue
                rec = {"ts": float(ts), "end": float(ts) + float(dur),
                       "where": where}
                if ev["name"] == TRANSFER_SPAN:
                    transfers[int(args["seq"])] = rec
                else:
                    rec["hit"] = bool(args.get("hit"))
                    waits[int(args["seq"])] = rec
            if (ev.get("cat") == SCHED_CAT and ev["name"] == STEP_SPAN
                    and "step" not in args):
                errors.append(f"{where}: sched step span missing args.step")
    # per-handle ordering: execution-start <= complete (span dur >= 0,
    # checked) and the wait resolves no earlier than the transfer
    # completes — a blocked wait ends at completion, an overlapped wait
    # starts after it. A blocked wait may START before the transfer span
    # (the span excludes queue time), so wait-start is only checked for
    # overlapped waits.
    for seq, w in waits.items():
        t = transfers.get(seq)
        if t is None:
            continue   # transfer span evicted from the ring before export
        if w["end"] + _EPS_US < t["end"]:
            errors.append(
                f"{w['where']}: wait for seq {seq} resolved at "
                f"{w['end']:.1f}us before its transfer completed at "
                f"{t['end']:.1f}us")
        if w["hit"] and w["ts"] + _EPS_US < t["end"]:
            errors.append(
                f"{w['where']}: overlapped wait for seq {seq} started "
                "before the transfer completed")
    if n_spans == 0:
        errors.append("trace contains no complete spans")
    return errors


def validate_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: not readable JSON ({e})"]
    return validate_events(obj)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.check",
        description="validate an exported Chrome trace-event file against "
                    "the repro.obs span schema")
    ap.add_argument("trace", help="path to a trace JSON file")
    args = ap.parse_args(argv)
    errors = validate_file(args.trace)
    for e in errors:
        print(f"SCHEMA: {e}")
    if errors:
        return 1
    with open(args.trace) as f:
        n = len(json.load(f)["traceEvents"])
    print(f"{args.trace}: valid Chrome trace ({n} events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
