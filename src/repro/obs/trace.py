"""Structured tracing: bounded in-memory ring + Chrome trace-event export.

The paper's latency-hiding claim is a statement about *when* things happen
— a transfer is hidden only if it runs under compute that was going to
happen anyway. Aggregate counters cannot show that; a trace can. `Tracer`
collects structured events (monotonic ``time.perf_counter`` timestamps,
category, name, args) into a bounded ring (oldest events drop first, so a
long-running server never grows without bound) and exports them as Chrome
trace-event JSON — loadable directly in Perfetto (https://ui.perfetto.dev)
or ``chrome://tracing``.

Event kinds mirror the trace-event format:

- **complete** (``ph="X"``) — a span with an explicit start + duration;
  the instrumented sites emit these at span *end*, so an event's presence
  implies the work finished;
- **instant** (``ph="i"``) — a point event (request state transitions,
  spill cascade hops, prefix lookups).

`NullTracer` is the disabled implementation: every method is a no-op and
``enabled`` is False so hot paths can skip building args dicts entirely —
telemetry off must cost nothing. Instrumented subsystems take a
``tracer=None`` kwarg and normalize it via ``or NULL_TRACER``.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["TraceEvent", "Tracer", "NullTracer", "NULL_TRACER"]


class TraceEvent:
    """One trace event. ``ts``/``dur`` are raw ``time.perf_counter``
    seconds; the exporter rebases them to microseconds."""

    __slots__ = ("cat", "name", "ph", "ts", "dur", "tid", "args")

    def __init__(self, cat: str, name: str, ph: str, ts: float,
                 dur: float = 0.0, tid: int = 0,
                 args: Optional[Dict[str, Any]] = None) -> None:
        self.cat = cat
        self.name = name
        self.ph = ph            # "X" complete span | "i" instant
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.args = args or {}

    @property
    def end(self) -> float:
        return self.ts + self.dur

    def __repr__(self) -> str:
        return (f"TraceEvent({self.cat}/{self.name} ph={self.ph} "
                f"ts={self.ts:.6f} dur={self.dur:.6f})")


class Tracer:
    """Bounded-ring structured tracer (see module doc). Thread-safe: the
    transfer engine's workers emit from their own threads."""

    enabled = True

    def __init__(self, capacity: int = 65536) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0
        self._t0 = time.perf_counter()   # export time base

    # -- emission ------------------------------------------------------
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def complete(self, cat: str, name: str, ts: float, dur: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """A span that ran [ts, ts+dur] (emitted at span end)."""
        self._push(TraceEvent(cat, name, "X", ts, max(dur, 0.0),
                              threading.get_ident(), args))

    def instant(self, cat: str, name: str,
                args: Optional[Dict[str, Any]] = None,
                ts: Optional[float] = None) -> None:
        self._push(TraceEvent(cat, name, "i",
                              self.now() if ts is None else ts,
                              0.0, threading.get_ident(), args))

    @contextmanager
    def span(self, cat: str, name: str, **args: Any) -> Iterator[None]:
        t0 = self.now()
        try:
            yield
        finally:
            self.complete(cat, name, t0, self.now() - t0, args or None)

    def _push(self, ev: TraceEvent) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1   # deque(maxlen) evicts the OLDEST
            self._ring.append(ev)

    # -- reading / export ----------------------------------------------
    def __len__(self) -> int:
        return len(self._ring)

    def events(self) -> List[TraceEvent]:
        """Snapshot of the ring, oldest first (newest always retained)."""
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped = 0

    def snapshot(self) -> Dict[str, int]:
        return {"events": len(self._ring), "dropped": self.dropped,
                "capacity": self.capacity}

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON object (the ``traceEvents`` dict form).
        Timestamps are rebased to microseconds since the tracer's epoch;
        thread idents are remapped to small stable tids, named via ``M``
        metadata events so Perfetto shows readable lanes."""
        events = self.events()
        tids: Dict[int, int] = {}
        out: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": "hyperoffload"},
        }]
        rows: List[Dict[str, Any]] = []
        for ev in events:
            tid = tids.setdefault(ev.tid, len(tids))
            row: Dict[str, Any] = {
                "name": ev.name, "cat": ev.cat, "ph": ev.ph,
                "ts": (ev.ts - self._t0) * 1e6, "pid": 1, "tid": tid,
            }
            if ev.ph == "X":
                row["dur"] = ev.dur * 1e6
            if ev.ph == "i":
                row["s"] = "t"   # thread-scoped instant
            if ev.args:
                row["args"] = ev.args
            rows.append(row)
        for ident, tid in tids.items():
            out.append({"name": "thread_name", "ph": "M", "pid": 1,
                        "tid": tid, "args": {"name": f"thread-{tid}"}})
        out.extend(rows)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def export(self, path: str) -> None:
        """Write the Chrome trace-event JSON file (open in Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


class _NullSpan:
    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every method is a no-op. Hot paths gate arg
    construction on ``tracer.enabled`` so disabling telemetry costs one
    attribute read per site."""

    enabled = False
    dropped = 0
    capacity = 0

    now = staticmethod(time.perf_counter)

    def complete(self, cat: str, name: str, ts: float, dur: float,
                 args: Optional[Dict[str, Any]] = None) -> None:
        pass

    def instant(self, cat: str, name: str,
                args: Optional[Dict[str, Any]] = None,
                ts: Optional[float] = None) -> None:
        pass

    def span(self, cat: str, name: str, **args: Any) -> _NullSpan:
        return _NULL_SPAN

    def __len__(self) -> int:
        return 0

    def events(self) -> List[TraceEvent]:
        return []

    def clear(self) -> None:
        pass

    def snapshot(self) -> Dict[str, int]:
        return {"events": 0, "dropped": 0, "capacity": 0}


#: the shared no-op tracer — subsystems normalize ``tracer or NULL_TRACER``
NULL_TRACER = NullTracer()
