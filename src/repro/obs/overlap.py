"""Transfer-overlap analysis: the paper's latency-hiding claim, measured.

HyperOffload's thesis is that graph-driven scheduling hides remote-memory
latency behind compute-intensive regions. The aggregate counters
(`TransferStats.waits_overlapped` / `waits_blocked` / `blocked_s`) say how
often a consumer found its transfer done; this analyzer reconstructs the
*time decomposition* from the trace:

- every transfer emits a ``transfer`` span (execution start → complete —
  queue time is excluded, so it can't masquerade as hidden time; it shows
  up as ``transfer.backpressure`` instead) carrying its handle ``seq`` and
  source/destination tiers;
- every first consumer wait emits a ``transfer.wait`` span (wait start →
  wait end) with ``hit`` = the transfer was already done.

For one transfer, **exposed** time is its wait's duration when the wait
blocked (the consumer stalled for exactly that long), and **hidden** time
is the rest of the in-flight interval — transfer work that ran under
compute/host work the pipeline was doing anyway. A transfer no consumer
ever waited on (engine-internal retirement) is fully hidden.

``hidden_fraction = hidden / (hidden + exposed)`` is the direct
measurement of the claim: 1.0 means every transferred byte moved behind
something else; 0.0 means the pipeline is synchronous in disguise.

The decomposition is broken out per source→destination tier pair and per
scheduler step (transfers attributed to the ``sched/step`` span their wait
fell in), and ``validate`` cross-checks it against the counters
`TransferStats` already keeps — trace and counters are independent
recordings of the same waits, so disagreement means instrumentation rot.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.obs.trace import TraceEvent, Tracer

__all__ = ["OverlapAnalyzer"]

#: trace schema names this analyzer (and the checker) key on
TRANSFER_SPAN = "transfer"
WAIT_SPAN = "transfer.wait"
STEP_SPAN = "step"
SCHED_CAT = "sched"
TRANSFER_CAT = "transfer"


@dataclass
class _Transfer:
    seq: int
    issue: float
    complete: float
    src: Optional[str] = None
    dst: Optional[str] = None
    wait_start: Optional[float] = None
    wait_end: Optional[float] = None
    hit: Optional[bool] = None    # wait found it done (None = never waited)

    @property
    def exposed_s(self) -> float:
        if self.hit is False:
            return max(self.wait_end - self.wait_start, 0.0)
        return 0.0

    @property
    def hidden_s(self) -> float:
        return max((self.complete - self.issue) - self.exposed_s, 0.0)

    @property
    def tier_pair(self) -> str:
        return f"{self.src or '?'}->{self.dst or '?'}"


def _bucket(into: Dict[str, Any], t: _Transfer) -> None:
    into["transfers"] += 1
    into["hidden_s"] += t.hidden_s
    into["exposed_s"] += t.exposed_s
    if t.hit is True:
        into["waits_overlapped"] += 1
    elif t.hit is False:
        into["waits_blocked"] += 1


def _new_bucket() -> Dict[str, Any]:
    return {"transfers": 0, "hidden_s": 0.0, "exposed_s": 0.0,
            "waits_overlapped": 0, "waits_blocked": 0}


def _finish_bucket(b: Dict[str, Any]) -> Dict[str, Any]:
    total = b["hidden_s"] + b["exposed_s"]
    b["hidden_fraction"] = (b["hidden_s"] / total) if total > 0 else None
    return b


class OverlapAnalyzer:
    """Post-process a trace into the hidden/exposed transfer-time
    decomposition (see module doc)."""

    def __init__(self, events: Iterable[TraceEvent]) -> None:
        self.transfers: Dict[int, _Transfer] = {}
        waits: List[Tuple[int, float, float, bool]] = []
        self.steps: List[Tuple[float, float, int]] = []   # (t0, t1, step)
        for ev in events:
            if ev.cat == TRANSFER_CAT and ev.name == TRANSFER_SPAN:
                seq = int(ev.args["seq"])
                self.transfers[seq] = _Transfer(
                    seq=seq, issue=ev.ts, complete=ev.end,
                    src=ev.args.get("src"), dst=ev.args.get("dst"))
            elif ev.cat == TRANSFER_CAT and ev.name == WAIT_SPAN:
                waits.append((int(ev.args["seq"]), ev.ts, ev.end,
                              bool(ev.args.get("hit"))))
            elif ev.cat == SCHED_CAT and ev.name == STEP_SPAN:
                self.steps.append((ev.ts, ev.end,
                                   int(ev.args.get("step", len(self.steps)))))
        self.steps.sort()
        # waits whose transfer span fell off the ring are dropped (a ring
        # keeps newest events; a wait always outlives its transfer span's
        # emission, so the orphan is the transfer, not the wait)
        self.orphan_waits = 0
        for seq, t0, t1, hit in waits:
            t = self.transfers.get(seq)
            if t is None:
                self.orphan_waits += 1
                continue
            t.wait_start, t.wait_end, t.hit = t0, t1, hit

    @classmethod
    def from_tracer(cls, tracer: Tracer) -> "OverlapAnalyzer":
        return cls(tracer.events())

    # ------------------------------------------------------------------
    def _step_of(self, t: _Transfer) -> Optional[int]:
        """The scheduler step whose span contains the transfer's wait
        (where exposure is charged); un-waited transfers attribute by
        their issue time."""
        at = t.wait_start if t.wait_start is not None else t.issue
        i = bisect.bisect_right(self.steps, (at, float("inf"), 1 << 62)) - 1
        if i >= 0 and self.steps[i][0] <= at <= self.steps[i][1]:
            return self.steps[i][2]
        return None

    def report(self) -> Dict[str, Any]:
        """The full decomposition: totals, per tier pair, per step."""
        total = _new_bucket()
        by_tier: Dict[str, Dict[str, Any]] = {}
        by_step: Dict[int, Dict[str, Any]] = {}
        inflight_s = 0.0
        for t in self.transfers.values():
            _bucket(total, t)
            inflight_s += max(t.complete - t.issue, 0.0)
            _bucket(by_tier.setdefault(t.tier_pair, _new_bucket()), t)
            step = self._step_of(t)
            if step is not None:
                _bucket(by_step.setdefault(step, _new_bucket()), t)
        out = _finish_bucket(total)
        out["inflight_s"] = inflight_s
        out["orphan_waits"] = self.orphan_waits
        out["by_tier"] = {k: _finish_bucket(v)
                          for k, v in sorted(by_tier.items())}
        out["by_step"] = [dict(step=k, **_finish_bucket(v))
                          for k, v in sorted(by_step.items())]
        return out

    def validate(self, transfer_stats: Mapping[str, float], *,
                 tol_s: float = 5e-3) -> List[str]:
        """Cross-check the trace decomposition against a
        ``TransferStats.snapshot()``: wait counts must match exactly and
        the trace's exposed time must equal ``blocked_s`` within ``tol_s``
        (both sides measure the same waits, so this is an instrumentation
        invariant, not a statistical one). Returns discrepancy messages
        (empty list = consistent). Skipped counts are tolerated only when
        the ring dropped events (``orphan_waits``)."""
        r = self.report()
        errors: List[str] = []
        seen_waits = r["waits_overlapped"] + r["waits_blocked"] \
            + self.orphan_waits
        stat_waits = (int(transfer_stats["waits_overlapped"])
                      + int(transfer_stats["waits_blocked"]))
        if self.orphan_waits == 0:
            for key in ("waits_overlapped", "waits_blocked"):
                if r[key] != int(transfer_stats[key]):
                    errors.append(f"{key}: trace={r[key]} "
                                  f"stats={int(transfer_stats[key])}")
        elif seen_waits != stat_waits:
            errors.append(f"total waits: trace={seen_waits} "
                          f"stats={stat_waits}")
        if self.orphan_waits == 0:
            diff = abs(r["exposed_s"] - float(transfer_stats["blocked_s"]))
            if diff > tol_s:
                errors.append(
                    f"exposed_s {r['exposed_s']:.6f} vs stats blocked_s "
                    f"{float(transfer_stats['blocked_s']):.6f} "
                    f"(|diff| {diff:.6f} > tol {tol_s})")
        return errors
