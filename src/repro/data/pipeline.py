"""Deterministic synthetic token pipeline.

Generates a reproducible Markov-ish token stream entirely from a counter
(threefry on step index) so every data-parallel shard can materialize its
slice independently — no host broadcast, no file I/O, shardable by
construction. Learnable structure: next-token depends on the previous token
through a fixed random permutation + noise, so a real model trains to a
loss visibly below uniform.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1           # fraction of uniformly random tokens

    def batch(self, step: int, cfg: Optional[ModelConfig] = None) -> Dict[str, jax.Array]:
        """Materialize the full global batch for ``step`` (tests / 1-host)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        perm = jax.random.permutation(jax.random.key(self.seed + 1), v)
        first = jax.random.randint(k1, (b, 1), 0, v)

        def step_fn(tok, k):
            nxt = perm[tok]
            u = jax.random.uniform(k, tok.shape)
            rnd = jax.random.randint(jax.random.fold_in(k, 1), tok.shape, 0, v)
            return jnp.where(u < self.noise, rnd, nxt), None

        keys = jax.random.split(k2, s)
        def scan_body(tok, k):
            nxt, _ = step_fn(tok, k)
            return nxt, nxt
        _, seq = jax.lax.scan(scan_body, first[:, 0], keys)
        tokens = jnp.concatenate([first, seq.T[:, :-1]], axis=1)
        targets = seq.T
        out = {"tokens": tokens, "targets": targets}
        if cfg is not None:
            out.update(self._frontend(cfg, k3))
        return out

    def _frontend(self, cfg: ModelConfig, key) -> Dict[str, jax.Array]:
        """Frontend-stub extras for audio / vision archs."""
        b, s = self.global_batch, self.seq_len
        if cfg.frontend == "audio":
            return {"enc_embeds": 0.1 * jax.random.normal(
                key, (b, cfg.encoder.n_frames, cfg.d_model))}
        if cfg.frontend == "vision":
            n_vis = max(1, s // 8)
            mask = jnp.zeros((b, s), bool).at[:, :n_vis].set(True)
            emb = jnp.zeros((b, s, cfg.d_model)).at[:, :n_vis].set(
                0.1 * jax.random.normal(key, (b, n_vis, cfg.d_model)))
            pos = jnp.broadcast_to(jnp.arange(s)[None, None, :], (3, b, s))
            return {"vision_embeds": emb, "vision_mask": mask,
                    "positions": pos.astype(jnp.int32)}
        return {}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs matching SyntheticTokens.batch (dry-run inputs)."""
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds((global_batch, seq_len), jnp.int32),
           "targets": sds((global_batch, seq_len), jnp.int32)}
    if cfg.frontend == "audio":
        out["enc_embeds"] = sds((global_batch, cfg.encoder.n_frames, cfg.d_model), dtype)
    elif cfg.frontend == "vision":
        out["vision_embeds"] = sds((global_batch, seq_len, cfg.d_model), dtype)
        out["vision_mask"] = sds((global_batch, seq_len), jnp.bool_)
        out["positions"] = sds((3, global_batch, seq_len), jnp.int32)
    return out
