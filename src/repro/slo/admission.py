"""Goodput-maximizing admission control.

``GoodputController`` is the scheduler's SLO brain for *which queued work
is worth admitting*: it tracks the measured per-step prefill rate (the
same signal the obs registry's prefill counters expose, kept here as a
cheap EWMA so the feasibility estimate adapts to the boost level actually
achieved), declares requests whose TTFT deadline is already unmeetable
**infeasible** so the scheduler sheds them before they waste prefill
(admitted-then-missed work is the overload failure mode FIFO exhibits),
and raises the chunked-prefill token budget under deadline pressure —
bounded by ``SLOConfig.max_prefill_boost`` so deadline-pressed prompts
cannot starve running decodes without limit.

Retirement accounting flows through ``note_retired``: deadline-met tokens
accumulate into ``goodput_tokens`` (the benchmark's goodput numerator) and
each TTFT-deadline request observes its deadline-relative slack into the
``req_ttft_slack_steps`` histogram (negative buckets = missed-by).

No imports from ``repro.sched`` — states are duck-typed.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Iterable, Optional

from repro.obs.metrics import MetricsRegistry
from repro.slo.policy import SLOConfig, slo_of, slo_outcome

#: deadline-relative TTFT slack histogram buckets (steps; negative =
#: missed by that much)
SLACK_BUCKETS = (-64, -16, -4, -1, 0, 1, 4, 16, 64)

_EWMA_ALPHA = 0.2


class GoodputController:
    def __init__(self, cfg: SLOConfig,
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.cfg = cfg
        # raw counters (session collectors merge these across schedulers)
        self.goodput_tokens = 0
        self.met_requests = 0
        self.missed_requests = 0
        self.boosted_steps = 0
        self._prefill_ewma: Optional[float] = None
        self._g_rate = self._h_slack = None
        if metrics is not None:
            self._g_rate = metrics.gauge(
                "slo_prefill_tokens_per_step",
                "EWMA of prefill tokens actually landed per scheduler step")
            self._h_slack = metrics.histogram(
                "req_ttft_slack_steps", SLACK_BUCKETS,
                "TTFT deadline minus achieved TTFT, scheduler steps "
                "(negative = deadline missed by that much)")

    # -- measured prefill rate -----------------------------------------
    def note_step(self, prefill_tokens: int) -> None:
        """Feed one step's landed prefill tokens. Idle steps (no prefill
        work) don't decay the estimate — the rate measures what a step
        *can* land, not utilization."""
        if prefill_tokens <= 0:
            return
        if self._prefill_ewma is None:
            self._prefill_ewma = float(prefill_tokens)
        else:
            self._prefill_ewma += _EWMA_ALPHA * (prefill_tokens
                                                 - self._prefill_ewma)
        if self._g_rate is not None:
            self._g_rate.set(self._prefill_ewma)

    def rate(self, base: int) -> float:
        """Prefill tokens per step for feasibility estimates: the measured
        EWMA, floored at the configured base budget (the scheduler always
        runs at least one chunk per step when prefill work exists)."""
        if self._prefill_ewma is None:
            return float(base)
        return max(float(base), self._prefill_ewma)

    # -- admission-time feasibility ------------------------------------
    def infeasible(self, state: Any, now: float,
                   est_prefill_steps: float) -> bool:
        """True when the request's TTFT deadline cannot be met even if
        admitted *right now* (optimistic estimate: no further queueing).
        Only such certainly-hopeless requests are shed."""
        if not self.cfg.shed_infeasible:
            return False
        spec = slo_of(state)
        if spec.ttft_deadline is None:
            return False
        return now + est_prefill_steps > state.request.arrival \
            + spec.ttft_deadline

    # -- deadline-pressure prefill boost -------------------------------
    def boost_budget(self, base: int, mid_states: Iterable[Any],
                     now: float) -> int:
        """Per-step prefill token budget, raised when a mid-prefill
        request's remaining prompt cannot land within its TTFT slack at
        the base rate; capped at ceil(base * max_prefill_boost)."""
        need = 0
        for s in mid_states:
            spec = slo_of(s)
            if spec.ttft_deadline is None:
                continue
            remaining = s.request.prompt_len - s.prefill_pos
            if remaining <= 0:
                continue
            slack = s.request.arrival + spec.ttft_deadline - now
            need = max(need, math.ceil(remaining / max(slack, 1.0)))
        cap = max(math.ceil(base * self.cfg.max_prefill_boost), base)
        budget = min(max(base, need), cap)
        if budget > base:
            self.boosted_steps += 1
        return budget

    # -- retirement accounting -----------------------------------------
    def note_retired(self, state: Any) -> None:
        """Accumulate one finished (DONE or SHED) state's outcome."""
        o = slo_outcome(state)
        if o["shed"]:
            return   # SchedStats.shed is the canonical shed counter
        if o["met"]:
            self.met_requests += 1
            self.goodput_tokens += o["tokens"]
        else:
            self.missed_requests += 1
        if self._h_slack is not None and o["ttft_slack"] is not None:
            self._h_slack.observe(o["ttft_slack"])

    def snapshot(self) -> Dict[str, int]:
        """Raw counters only (no ratios) — the session's sched collector
        merges these numerically across schedulers."""
        return {"goodput_tokens": self.goodput_tokens,
                "met_requests": self.met_requests,
                "missed_requests": self.missed_requests,
                "boosted_steps": self.boosted_steps}
