"""SLO policy vocabulary: priority classes, per-request deadlines, and
attainment accounting.

An ``SLOSpec`` is attached to a ``Request`` at submission (``request.slo``)
and threaded through the scheduler untouched: ``priority_class`` orders
admission and picks preemption victims, ``ttft_deadline`` /
``tpot_deadline`` (virtual scheduler steps, relative to arrival) decide
whether a finished request's tokens count toward *goodput* — the
deadline-met token throughput the admission controller maximizes under
overload ("Memory Offloading for LLM Inference with Latency SLO
Guarantees", PAPERS.md).

Everything here is pure policy: no imports from ``repro.sched`` (the
scheduler imports *us*), states are duck-typed ``RequestState``-likes, and
``attainment_summary`` works on any finished-state iterable — the
benchmark uses it to score a FIFO run of the same annotated trace post
hoc, so FIFO vs SLO-aware comparisons share one scoring implementation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, Optional, Tuple

#: class name -> rank; higher rank is admitted first and never preempted
#: by a lower rank.
PRIORITY_CLASSES: Dict[str, int] = {"batch": 0, "standard": 1,
                                    "interactive": 2}

#: status string a shed request carries (mirrors ``sched.requests.SHED`` —
#: kept as a literal so policy code never imports the scheduler).
_SHED = "SHED"


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One request's service-level objective.

    Deadlines are in virtual scheduler steps relative to ``arrival``:
    ``ttft_deadline`` bounds arrival → first token, ``tpot_deadline``
    bounds the mean per-output-token latency after the first token
    (matching the ``req_time_per_output_token_steps`` histogram). ``None``
    means unconstrained — a request with no deadlines always counts as
    met, so pure-throughput traffic is goodput by definition."""

    priority_class: str = "standard"
    ttft_deadline: Optional[float] = None
    tpot_deadline: Optional[float] = None

    def __post_init__(self) -> None:
        if self.priority_class not in PRIORITY_CLASSES:
            raise ValueError(
                f"priority_class {self.priority_class!r} not in "
                f"{sorted(PRIORITY_CLASSES)}")
        for name in ("ttft_deadline", "tpot_deadline"):
            v = getattr(self, name)
            if v is not None and not v > 0:
                raise ValueError(f"{name} must be > 0 (or None), got {v!r}")

    @property
    def rank(self) -> int:
        return PRIORITY_CLASSES[self.priority_class]


DEFAULT_SLO = SLOSpec()


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """SLO-aware scheduling knobs (``OffloadConfig.slo``). Disabled by
    default — the scheduler then keeps pure FIFO + capacity admission and
    every counter stays zero."""

    enable: bool = False
    #: park a lower-priority sequence's KV rows to seat a deadline-pressed
    #: higher-priority arrival (the PR 4 park/restore path as a preemption
    #: primitive)
    preemption: bool = True
    #: drop requests whose TTFT deadline is already unmeetable *before*
    #: admission (goodput: no prefill spent on certainly-missed work)
    shed_infeasible: bool = True
    #: deadline pressure may raise the per-step prefill token budget up to
    #: ceil(base * max_prefill_boost) (chunked prefill only)
    max_prefill_boost: float = 4.0
    #: preemptions allowed per scheduler step (thrash guard)
    max_preempt_per_step: int = 1

    def __post_init__(self) -> None:
        if not self.max_prefill_boost >= 1.0:
            raise ValueError("slo.max_prefill_boost must be >= 1.0, "
                             f"got {self.max_prefill_boost!r}")
        if self.max_preempt_per_step < 0:
            raise ValueError("slo.max_preempt_per_step must be >= 0, "
                             f"got {self.max_preempt_per_step!r}")


def slo_of(state: Any) -> SLOSpec:
    """The state's spec, defaulting unannotated requests to ``standard``
    with no deadlines."""
    spec = getattr(state.request, "slo", None)
    return spec if spec is not None else DEFAULT_SLO


def candidate_key(state: Any) -> Tuple[float, float, float, int]:
    """Admission order among ready requests: highest priority class first,
    then earliest absolute TTFT deadline, then FIFO (arrival, id) — sort
    ascending and the best candidate is ``min``."""
    spec = slo_of(state)
    req = state.request
    deadline = (math.inf if spec.ttft_deadline is None
                else req.arrival + spec.ttft_deadline)
    return (-spec.rank, deadline, req.arrival, req.req_id)


def slo_outcome(state: Any) -> Dict[str, Any]:
    """Score one finished (DONE or SHED) state against its spec.

    ``ttft_ok``/``tpot_ok`` are ``None`` when the corresponding deadline is
    unset (not part of the attainment denominator). A shed request with a
    TTFT deadline counts as a TTFT *miss* — shedding must not launder the
    attainment figure. ``met`` (and thus ``met_tokens``) requires every set
    deadline to hold."""
    spec = slo_of(state)
    req = state.request
    shed = state.status == _SHED
    tokens = len(state.out)
    ttft = (None if state.t_first_token is None
            else state.t_first_token - req.arrival)
    ttft_ok = ttft_slack = None
    if spec.ttft_deadline is not None:
        ttft_ok = ttft is not None and ttft <= spec.ttft_deadline
        if ttft is not None:
            ttft_slack = spec.ttft_deadline - ttft
    tpot_ok = None
    if spec.tpot_deadline is not None:
        if state.t_done is None or state.t_first_token is None:
            tpot_ok = False
        else:
            tpot = ((state.t_done - state.t_first_token)
                    / max(tokens - 1, 1))
            tpot_ok = tpot <= spec.tpot_deadline
    met = not shed and ttft_ok is not False and tpot_ok is not False
    return {"class": spec.priority_class, "shed": shed, "tokens": tokens,
            "met": met, "met_tokens": tokens if met else 0, "ttft": ttft,
            "ttft_ok": ttft_ok, "ttft_slack": ttft_slack,
            "tpot_ok": tpot_ok}


def attainment_summary(states: Iterable[Any]) -> Dict[str, Any]:
    """Aggregate ``slo_outcome`` over finished states: overall request/
    token/goodput counts plus a per-class breakdown with TTFT/TPOT
    attainment fractions (``None`` when no request in the class carries
    that deadline). Shared by the benchmark, launchers, and tests."""
    total: Dict[str, Any] = {"requests": 0, "shed": 0, "tokens": 0,
                             "met_tokens": 0}
    classes: Dict[str, Dict[str, Any]] = {}
    for st in states:
        o = slo_outcome(st)
        c = classes.setdefault(o["class"], {
            "requests": 0, "shed": 0, "tokens": 0, "met_tokens": 0,
            "ttft_n": 0, "ttft_met": 0, "tpot_n": 0, "tpot_met": 0})
        for d in (total, c):
            d["requests"] += 1
            d["shed"] += int(o["shed"])
            d["tokens"] += o["tokens"]
            d["met_tokens"] += o["met_tokens"]
        if o["ttft_ok"] is not None:
            c["ttft_n"] += 1
            c["ttft_met"] += int(o["ttft_ok"])
        if o["tpot_ok"] is not None:
            c["tpot_n"] += 1
            c["tpot_met"] += int(o["tpot_ok"])
    for c in classes.values():
        c["ttft_attainment"] = (c["ttft_met"] / c["ttft_n"]
                                if c["ttft_n"] else None)
        c["tpot_attainment"] = (c["tpot_met"] / c["tpot_n"]
                                if c["tpot_n"] else None)
    total["classes"] = classes
    return total
