"""SLO-aware scheduling policy layer.

- ``policy``    — ``SLOSpec`` (priority class + TTFT/TPOT deadlines,
  attached per request), ``SLOConfig`` (the ``OffloadConfig.slo`` block),
  admission ordering (``candidate_key``) and attainment scoring
  (``slo_outcome`` / ``attainment_summary``);
- ``admission`` — ``GoodputController``: measured-prefill-rate feasibility
  (early shedding of certainly-missed requests), deadline-pressure prefill
  budget boost, goodput accounting;
- ``preempt``   — ``PreemptionEngine``: when a deadline-pressed arrival is
  worth parking a running lower-priority sequence through the pool's
  park/restore path.

Pure policy over duck-typed request states: nothing here imports
``repro.sched`` (the scheduler imports this package).
"""

from repro.slo.admission import SLACK_BUCKETS, GoodputController
from repro.slo.policy import (
    DEFAULT_SLO, PRIORITY_CLASSES, SLOConfig, SLOSpec, attainment_summary,
    candidate_key, slo_of, slo_outcome,
)
from repro.slo.preempt import PreemptionEngine

__all__ = [
    "PRIORITY_CLASSES", "SLOSpec", "DEFAULT_SLO", "SLOConfig",
    "slo_of", "candidate_key", "slo_outcome", "attainment_summary",
    "GoodputController", "SLACK_BUCKETS",
    "PreemptionEngine",
]
