"""Deadline-driven preemption policy.

``PreemptionEngine`` decides *whether* seating a deadline-pressed arrival
is worth parking a running lower-priority sequence; the *mechanics* —
parking the victim's KV rows through the pool, freeing its slot, restoring
it when pressure drops — live in the scheduler, which already has the
page-by-page park/restore path (PR 4) this policy reuses as its
preemption primitive.

A victim is picked only when every cheaper option is exhausted:

- the candidate carries a TTFT deadline (pure-throughput work never
  preempts anyone);
- waiting for a natural retirement would miss the deadline (the earliest
  slot release, ``min(remaining_steps)``, is later than the candidate's
  slack);
- a running sequence of *strictly lower* priority class with more than one
  step of work left exists (never preempt within a class — FIFO fairness
  — and never park a sequence about to retire on its own);
- the per-step preemption quota (``SLOConfig.max_preempt_per_step``)
  isn't spent (thrash guard).

Among eligible victims the lowest class with the **most** remaining work
is parked: its pages will sit in the pool longest anyway, so parking it
costs the least progress per freed step.

No imports from ``repro.sched`` — states are duck-typed and the scheduler
passes its remaining-work estimator in as a callback.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

from repro.slo.policy import SLOConfig, slo_of


class PreemptionEngine:
    def __init__(self, cfg: SLOConfig) -> None:
        self.cfg = cfg
        self._this_step = 0

    def begin_step(self) -> None:
        """Reset the per-step preemption quota."""
        self._this_step = 0

    def pick_victim(self, candidate: Any, running: Sequence[Any],
                    now: float, *, est_prefill_steps: float,
                    remaining_steps: Callable[[Any], int]) -> Optional[Any]:
        """The running state to park so ``candidate`` can take its slot,
        or None when preemption is off-policy (see module doc)."""
        if not self.cfg.preemption or not running:
            return None
        if self._this_step >= self.cfg.max_preempt_per_step:
            return None
        spec = slo_of(candidate)
        if spec.ttft_deadline is None:
            return None
        slack = (candidate.request.arrival + spec.ttft_deadline
                 - now - est_prefill_steps)
        if slack >= min(remaining_steps(s) for s in running):
            return None   # a slot frees in time — patience suffices
        victims = [s for s in running
                   if slo_of(s).rank < spec.rank and remaining_steps(s) > 1]
        if not victims:
            return None
        self._this_step += 1
        return min(victims, key=lambda s: (slo_of(s).rank,
                                           -remaining_steps(s), s.req_id))
