"""HyperOffload reproduction: graph-driven hierarchical memory management
for LLMs, as a production-grade JAX framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"
