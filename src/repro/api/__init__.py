"""`repro.api` — the public front door.

One declarative `OffloadConfig` (tier topology, hardware, planner options,
transfer-depth policy, mode) and one `HyperOffloadSession` facade that owns
the pool / transfer engine / planner and constructs every subsystem
pre-wired to them. See `api.config` and `api.session` module docs; dump the
default config with ``python -m repro.api --print-config``.

Migration from the old per-subsystem constructors:

=====================================  =====================================
old call site                          through the front door
=====================================  =====================================
``ServeEngine(m, p, offload_kv=True)`` ``OffloadConfig(mode="kv_offload")``;
                                       ``session.serve_engine(m, p)``
``ContinuousScheduler(m, p,            ``session.scheduler(m, p)`` (fields
SchedulerConfig(...), pool=pool)``     from the config, kwargs override)
``PagedKVCache.create(..., pool=...)`` ``session.paged_kv(batch=..., ...)``
``PlanExecutor(g, fns, pool=...)``     ``session.executor(g, fns)``
``make_train_step(m, TrainStepConfig(  ``session.train_step(m,
remat=..., offload_opt_state=...))``   total_steps=...)``
``TransferEngine(depth=<magic>)``      ``transfer_depth="auto"`` (policy:
                                       ``pool.auto_depth``)
``InsertionOptions(min_bytes=1)``      mode default (``insertion=None``)
=====================================  =====================================
"""

from repro.api.config import HW_SPECS, KVCodecConfig, MODES, OffloadConfig
from repro.api.session import HyperOffloadSession

__all__ = [
    "KVCodecConfig",
    "OffloadConfig",
    "HyperOffloadSession",
    "HW_SPECS",
    "MODES",
]
