"""`HyperOffloadSession` — the one front door to the runtime.

The paper's thesis is a single globally-visible layer owning both the
compile-time plan and the runtime data movement. The session is that layer
on the API surface: it owns exactly **one** `MemoryPoolManager`, **one**
`TransferEngine`, and **one** `HyperOffloadPlanner`, and hands out every
subsystem pre-wired to them:

    cfg = OffloadConfig(mode="kv_offload", max_seq=64)
    with HyperOffloadSession(cfg) as session:
        engine = session.serve_engine(model, params)
        sched  = session.scheduler(model, params)
        cache  = session.paged_kv(batch=2, n_kv_heads=4, head_dim=64)
        ex     = session.executor(graph, compute_fns)
        step   = session.train_step(model, total_steps=100)
        print(session.stats())          # pool + transfer + serve + sched

Everything the session hands out shares its pool (one capacity ledger, one
eviction hierarchy), its plan cache (a decode-step plan computed for one
scheduler is reused by the next), and its transfer engine — whose in-flight
depth grows to cover the largest consumer via the ``auto`` depth policy
(`pool.auto_depth`) instead of each call site hard-coding its own.

Offload-mode subsystems refuse to build private pools (the old one-release
deprecation shims are gone): `ServeEngine(offload_kv=True)`,
`ContinuousScheduler(kv_offload=True)`, and `PagedKVCache.create()` all
require an explicit pool — construct through the session.

With ``config.prefix_cache.enable`` the session also owns one
`PrefixCacheManager` (``repro.prefix``): every scheduler it hands out
shares the same radix index and cached pages, so one request's retired
prompt prefix serves every later scheduler's admissions, and the cached
pages live in the session pool under the same tiering/eviction ledger as
everything else.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.api.config import OffloadConfig
from repro.core.calibration import (
    CalibratedHardwareSpec, calibrate, measurements_from_pairs,
    required_inflight,
)
from repro.core.insertion import PAGED_INSERTION
from repro.core.ir import Graph
from repro.core.jax_exec import PlanExecutor
from repro.core.planner import HyperOffloadPlanner, OffloadPlan
from repro.obs import NULL_TRACER, MetricsRegistry, OverlapAnalyzer, Tracer
from repro.offload.kvcache import PagedKVCache
from repro.pool import DEVICE_TIER, MemoryPoolManager, default_pool
from repro.prefix import PrefixCacheManager
from repro.sched.scheduler import ContinuousScheduler, SchedulerConfig
from repro.serving.engine import ServeEngine
from repro.training.step import TrainStepConfig, make_train_step
from repro.training.step import init_train_state as _init_train_state


def _weighted_plan_lead(pairs: List[tuple]) -> float:
    """Session-level mean plan lead over (prefetch steps, per-scheduler
    mean lead) pairs, weighted by step count — an idle one-step scheduler
    must not skew the session figure the way an unweighted mean of means
    does. Falls back to the unweighted mean when no scheduler has stepped
    yet (all weights zero)."""
    total = sum(steps for steps, _ in pairs)
    if total > 0:
        return sum(steps * lead for steps, lead in pairs) / total
    return sum(lead for _, lead in pairs) / len(pairs)


class HyperOffloadSession:
    """One pool, one transfer engine, one planner — shared by every
    subsystem the session constructs (see module doc)."""

    def __init__(self, config: Optional[OffloadConfig] = None, *,
                 device: Optional[jax.Device] = None,
                 pool: Optional[MemoryPoolManager] = None) -> None:
        self.config = config if config is not None else OffloadConfig()
        c = self.config
        # ONE tracer + ONE metrics registry per session, shared by every
        # subsystem it hands out (repro.obs). The registry always exists —
        # stats() is a registry snapshot either way; the tracer is the
        # shared no-op NULL_TRACER unless telemetry is enabled.
        self.registry = MetricsRegistry()
        self.tracer = (Tracer(capacity=c.telemetry.ring_capacity)
                       if c.telemetry.enable else NULL_TRACER)
        self._owns_pool = pool is None
        if pool is None:
            # the config's declarative tier chain (explicit topology, or
            # the default device/host/remote under the legacy capacities)
            pool = default_pool(
                topology=c.tier_topology,
                device=device,
                transfer_depth=c.depth_for(),
                transfer_workers=c.transfer_workers,
                codec=c.kv_codec.codec if c.kv_codec.enabled else None,
                codec_below=c.kv_codec.below_tier,
                tracer=self.tracer if c.telemetry.enable else None)
        elif c.telemetry.enable:
            pool.set_tracer(self.tracer)
        self.pool = pool
        self.transfer = pool.transfer
        if c.transfer_depth != "auto":
            # the pin applies to injected pools too — subsystems must not
            # grow an explicitly configured depth
            self.transfer.ensure_depth(c.depth_for())
            self.transfer.depth_pinned = True
        # the session's *effective* hardware model: starts as the config's
        # static spec; recalibrate() swaps in a measured one
        self.hw = c.hardware
        self.planner = HyperOffloadPlanner(
            self.hw, insert_opts=c.insertion_options(),
            sched_opts=c.schedule)
        self._plan_cache: Dict[Any, OffloadPlan] = {}
        self._engines: List[ServeEngine] = []
        self._schedulers: List[ContinuousScheduler] = []
        self._paged: List[PagedKVCache] = []
        self.prefix_cache: Optional[PrefixCacheManager] = None
        if c.prefix_cache.enable:
            pc = c.prefix_cache
            self.prefix_cache = PrefixCacheManager(
                self.pool, page_size=pc.page_size, max_pages=pc.max_pages,
                min_match_pages=pc.min_match_pages, pin_tier=pc.pin_tier,
                tracer=self.tracer)
        self._register_collectors()
        self._closed = False

    def _register_collectors(self) -> None:
        """Re-home the subsystem stats snapshots onto the registry: each
        legacy counter block (`PoolStats`/`TransferStats` via the pool
        snapshot, `ServeStats`, `SchedStats`+prefetch, paged, prefix)
        becomes a named collector, and ``stats()`` is the registry's
        ``collect()``. Registration order is the stats() key order."""
        reg = self.registry
        reg.register_collector("mode", lambda: self.config.mode)
        reg.register_collector("pool", lambda: self.pool.snapshot())
        reg.register_collector("serve", self._collect_serve)
        reg.register_collector("sched", self._collect_sched)
        reg.register_collector("paged", self._collect_paged)
        reg.register_collector(
            "prefix", lambda: None if self.prefix_cache is None
            else self.prefix_cache.snapshot())
        reg.register_collector("plans_cached",
                               lambda: len(self._plan_cache))

    def _collect_serve(self) -> Dict[str, Any]:
        serve = {"engines": len(self._engines), "prefill_tokens": 0,
                 "decoded_tokens": 0, "cache_round_trips": 0}
        for e in self._engines:
            serve["prefill_tokens"] += e.stats.prefill_tokens
            serve["decoded_tokens"] += e.stats.decoded_tokens
            serve["cache_round_trips"] += e.stats.cache_round_trips
        return serve

    def _collect_sched(self) -> Dict[str, Any]:
        sched = {"schedulers": len(self._schedulers), "steps": 0, "joins": 0,
                 "retires": 0, "prefill_tokens": 0, "prefill_chunks": 0,
                 "decoded_tokens": 0, "pages_parked": 0, "cold_spills": 0,
                 "prefix_hits": 0, "prefix_hit_tokens": 0,
                 "preemptions": 0, "resumes": 0, "shed": 0,
                 "admission_blocked": 0}
        prefetch = {"steps": 0, "fetches_issued": 0, "layers_planned": 0}
        slo: Optional[Dict[str, int]] = None
        leads: List[tuple] = []
        for s in self._schedulers:
            for k in ("steps", "joins", "retires", "prefill_tokens",
                      "prefill_chunks", "decoded_tokens", "pages_parked",
                      "cold_spills", "prefix_hits", "prefix_hit_tokens",
                      "preemptions", "resumes", "shed"):
                sched[k] += getattr(s.stats, k)
            sched["admission_blocked"] += s.admission.blocked
            snap = s.slo_snapshot()
            if snap is not None:
                if slo is None:
                    slo = dict(snap)
                else:
                    for k, v in snap.items():
                        slo[k] = slo.get(k, 0) + v
            pf = s.prefetch_stats()
            if pf is not None:
                for k in ("steps", "fetches_issued", "layers_planned"):
                    prefetch[k] += int(pf[k])
                leads.append((int(pf["steps"]), pf["mean_plan_lead"]))
        if leads:
            prefetch["mean_plan_lead"] = _weighted_plan_lead(leads)
        sched["prefetch"] = prefetch
        if slo is not None:
            sched["slo"] = slo
        return sched

    def _collect_paged(self) -> Dict[str, Any]:
        paged = {"caches": len(self._paged), "fetches": 0, "flushes": 0,
                 "tokens": 0}
        for p in self._paged:
            paged["fetches"] += p.fetches
            paged["flushes"] += p.flushes
            paged["tokens"] += p.length
        return paged

    # -- planning -------------------------------------------------------
    def plan(self, graph: Graph, *, key: Optional[Any] = None,
             refine: Optional[bool] = None) -> OffloadPlan:
        """Plan ``graph`` with the session's planner. A hashable ``key``
        memoizes the plan in the session's cache (shared with the
        schedulers' `PlanPrefetcher`s)."""
        refine = self.config.refine if refine is None else refine
        cache_key = None if key is None else (key, refine)
        if cache_key is not None and cache_key in self._plan_cache:
            return self._plan_cache[cache_key]
        plan = self.planner.plan(graph, refine=refine)
        if cache_key is not None:
            self._plan_cache[cache_key] = plan
        return plan

    # -- closed-loop calibration ----------------------------------------
    def _overlap_window_s(self) -> float:
        """Measured overlap window per scheduler step: the
        ``admit_prefill`` span is the host work that sits between one
        step's fetch issue and the next step's wait, i.e. the time budget
        a step's transfers have to hide under. The *median* span, not the
        mean — first-admission spans absorb prefill compilation (hundreds
        of ms against a sub-ms typical step) and a mean window inflated
        by them would under-size prefetch parallelism for every steady
        step. 0.0 without telemetry or before any step ran."""
        durs = sorted(e.dur for e in self.tracer.events()
                      if e.cat == "sched" and e.name == "admit_prefill")
        if not durs:
            return 0.0
        n = len(durs)
        mid = n // 2
        return durs[mid] if n % 2 else (durs[mid - 1] + durs[mid]) / 2.0

    def recalibrate(self) -> CalibratedHardwareSpec:
        """Close the planning loop against measured reality.

        Folds the transfer engine's per tier-pair byte/busy-time table
        (every prefetch, put, spill and blocking get the hierarchy has
        performed so far) into a `CalibratedHardwareSpec`
        (``core.calibration``), then:

        - swaps the session planner for one running on the measured spec
          (every subsequent ``plan()`` uses measured transfer estimates);
        - re-plans every live scheduler (``ContinuousScheduler.replan``) so
          refined prefetch orders and plan leads reflect measured
          bandwidth — the calibrated spec's distinct name also keys fresh
          plan-cache entries, never aliasing static plans;
        - sizes prefetch parallelism to the measured bandwidth-delay
          product: if completing one step's fetches inside the measured
          overlap window needs more in-flight transfers than the engine
          has workers, the engine grows (up to
          ``config.calibration.max_inflight``). On a latency-dominated
          modeled tier this is the difference between serialized sleeps
          (exposed waits) and fully hidden transfers.

        Idempotent under unchanged traffic; cheap enough to call between
        benchmark phases or on a serving-loop cadence. Returns the
        calibrated spec (``pair_bw`` carries the measured table)."""
        cal = self.config.calibration
        measurements = measurements_from_pairs(
            self.transfer.stats.snapshot()["pairs"])
        spec = calibrate(self.hw, measurements,
                         device_tier=DEVICE_TIER,
                         min_transfers=cal.min_transfers,
                         min_bytes=cal.min_bytes)
        self.hw = spec
        self.planner = self.planner.with_hardware(spec)
        # measured in-flight sizing: worst per-step fetch fan-out across
        # the schedulers vs the measured per-step overlap window
        pages_per_step = max(
            (s.prefetcher.stats.mean_fetches_per_step
             for s in self._schedulers if s.prefetcher is not None),
            default=0.0)
        window = self._overlap_window_s()
        need = required_inflight(
            measurements, pages_per_step=pages_per_step, window_s=window,
            device_tier=DEVICE_TIER, cap=cal.max_inflight,
            min_transfers=cal.min_transfers, min_bytes=cal.min_bytes)
        if need > 0:
            self.transfer.ensure_workers(need)
            self.transfer.ensure_depth(need)
        for s in self._schedulers:
            s.replan(spec)
        return spec

    # -- serving --------------------------------------------------------
    def serve_engine(self, model, params, *, max_seq: Optional[int] = None,
                     cache_dtype=None,
                     offload_kv: Optional[bool] = None) -> ServeEngine:
        """A `ServeEngine` over the session pool. Offload behavior follows
        ``config.mode`` (``kv_offload`` ⇒ pool round trips); pass
        ``offload_kv`` to override per engine."""
        offload = self.config.offload_kv if offload_kv is None else offload_kv
        engine = ServeEngine(
            model, params,
            max_seq=self.config.max_seq if max_seq is None else max_seq,
            cache_dtype=cache_dtype if cache_dtype is not None
            else self.config.dtype,
            offload_kv=offload, pool=self.pool, tracer=self.tracer)
        self._engines.append(engine)
        return engine

    def scheduler(self, model, params,
                  cfg: Optional[SchedulerConfig] = None,
                  **overrides) -> ContinuousScheduler:
        """A `ContinuousScheduler` over the session pool and plan cache.
        The `SchedulerConfig` is derived from the session config; keyword
        ``overrides`` (``max_batch=…``, ``prefill_budget=…``, …) or a full
        ``cfg`` replace individual fields."""
        c = self.config
        if cfg is None:
            base: Dict[str, Any] = dict(
                max_batch=c.max_batch, max_seq=c.max_seq,
                prefill_budget=c.prefill_budget, chunk_size=c.chunk_size,
                prefill_tokens=c.prefill_tokens, kv_offload=c.offload_kv,
                cache_dtype=c.dtype, hw=self.hw,
                insert_opts=c.insertion_options(), refine=c.refine,
                slo=c.slo if c.slo.enable else None)
            base.update(overrides)
            if (base["kv_offload"] and c.insertion is None
                    and "insert_opts" not in overrides):
                # a kv_offload override on a non-offload-mode session must
                # still plan the mandatory prefetch of every pool-resident
                # KV tensor — the resident-mode cost-model thresholds would
                # silently filter small KV leaves out of the plan
                base["insert_opts"] = PAGED_INSERTION
            cfg = SchedulerConfig(**base)
        elif overrides:
            raise TypeError("pass either cfg or field overrides, not both")
        sched = ContinuousScheduler(
            model, params, cfg, pool=self.pool,
            plan_cache=self._plan_cache, prefix_cache=self.prefix_cache,
            tracer=self.tracer,
            metrics=self.registry if c.telemetry.enable else None)
        self._schedulers.append(sched)
        return sched

    def paged_kv(self, *, batch: int, n_kv_heads: int, head_dim: int,
                 max_seq: Optional[int] = None,
                 page_size: Optional[int] = None,
                 dtype=None,
                 device_pages: Optional[int] = None,
                 use_kernel: bool = False) -> PagedKVCache:
        """A `PagedKVCache` storing its pages in the session pool. (Each
        subsystem declares its own depth need to the shared engine — see
        `pool.auto_depth`.) ``device_pages``/``use_kernel`` size the fused
        decode path's device page buffer and pick its kernel (see
        ``PagedKVCache.attend_fused``)."""
        max_seq = self.config.max_seq if max_seq is None else max_seq
        page_size = self.config.page_size if page_size is None else page_size
        cache = PagedKVCache.create(
            batch=batch, max_seq=max_seq, page_size=page_size,
            n_kv_heads=n_kv_heads, head_dim=head_dim,
            dtype=dtype if dtype is not None else self.config.dtype,
            pool=self.pool, device_pages=device_pages,
            use_kernel=use_kernel)
        self._paged.append(cache)
        return cache

    # -- plan execution -------------------------------------------------
    def executor(self, graph: Graph, compute_fns: Mapping[str, Callable],
                 *, device: Optional[jax.Device] = None) -> PlanExecutor:
        """A sync `PlanExecutor` running against the session pool."""
        return PlanExecutor(graph, compute_fns, device=device, pool=self.pool)

    # -- training -------------------------------------------------------
    def train_config(self, **overrides) -> TrainStepConfig:
        """`TrainStepConfig` with the memory policy (remat mode, optimizer
        -state offload, host memory kind) taken from the session config;
        ``overrides`` set the optimization hyperparameters."""
        base: Dict[str, Any] = dict(
            remat=self.config.remat,
            offload_opt_state=self.config.offload_opt_state,
            host_kind=self.config.host_memory_kind)
        base.update(overrides)
        return TrainStepConfig(**base)

    def train_step(self, model, ts: Optional[TrainStepConfig] = None, *,
                   jit: bool = True, **overrides) -> Callable:
        if ts is not None and overrides:
            raise TypeError("pass either ts or field overrides, not both")
        return make_train_step(model, ts or self.train_config(**overrides),
                               jit=jit)

    def init_train_state(self, model, key, dtype=jnp.float32,
                         ts: Optional[TrainStepConfig] = None, **overrides):
        if ts is not None and overrides:
            raise TypeError("pass either ts or field overrides, not both")
        return _init_train_state(model, key, dtype,
                                 ts=ts or self.train_config(**overrides))

    # -- observability --------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        """One merged snapshot: pool (incl. transfer + per-tier occupancy)
        plus aggregated serve/sched/paged counters across every subsystem
        this session handed out. Implemented as the session registry's
        ``collect()`` (the legacy stats blocks are registered collectors),
        so the shape is identical whether telemetry is on or off — with
        telemetry on, one extra ``"telemetry"`` key carries the latency
        histograms and the trace-ring state."""
        out = self.registry.collect()
        if self.config.telemetry.enable:
            out["telemetry"] = {
                "histograms": self.registry.snapshot(),
                "trace": self.tracer.snapshot(),
            }
        return out

    def stats_text(self) -> str:
        """Prometheus-style text exposition of the same snapshot: the
        registry's typed instruments (request-latency histograms) plus the
        flattened collector counters."""
        return self.registry.render_prometheus()

    def overlap(self) -> Optional[Dict[str, Any]]:
        """`OverlapAnalyzer` report (hidden vs exposed transfer time per
        tier pair and per scheduler step) over the current trace ring, or
        ``None`` when telemetry is disabled."""
        if not self.config.telemetry.enable:
            return None
        return OverlapAnalyzer.from_tracer(self.tracer).report()

    def export_trace(self, path: str) -> None:
        """Write the trace ring as a Chrome trace-event / Perfetto JSON
        file. Raises when telemetry is disabled — there is nothing to
        export and silently writing an empty trace would mask the
        misconfiguration."""
        if not self.config.telemetry.enable:
            raise RuntimeError(
                "export_trace requires config.telemetry.enable")
        self.tracer.export(path)

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Idempotent: shut down every subsystem, then the pool (if owned).
        Subsystems never close the shared pool themselves. With
        ``telemetry.trace_path`` set, the trace ring is exported there
        before teardown (the drain in ``pool.close`` emits no new spans
        the consumer could still be interested in)."""
        if self._closed:
            return
        self._closed = True
        for s in self._schedulers:
            s.close()
        for e in self._engines:
            e.close()
        if self.prefix_cache is not None:
            self.prefix_cache.close()
        tp = self.config.telemetry.trace_path
        if self.config.telemetry.enable and tp is not None:
            self.tracer.export(tp)
        if self._owns_pool:
            self.pool.close()

    def __enter__(self) -> "HyperOffloadSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
