"""`OffloadConfig` — the one declarative description of an offload setup.

Before the `repro.api` front door, tier topology, planner options, and
transfer depths were scattered across five constructors with ad-hoc kwargs
and magic numbers. `OffloadConfig` owns all of it in a single frozen,
serializable object:

- **mode** — what the session is serving: ``resident`` (KV stays on
  device), ``kv_offload`` (whole-cache / per-page pool round trips),
  ``paged`` (page-granular `PagedKVCache` with sparse selection),
  ``continuous`` (continuous-batching scheduler, resident pages);
- **tier topology** — either the legacy per-tier byte capacities of the
  default device/host/remote chain (``None`` = unbounded), or a full
  declarative ``TierTopology`` (ordered ``TierSpec`` chain with backend
  kinds, admission roles and modeled latency/bandwidth), realized as one
  `MemoryPoolManager`;
- **calibration knobs** — thresholds the closed loop
  (``session.recalibrate()``) applies when folding measured per-tier-pair
  bandwidth back into the planner;
- **hardware** — a `HardwareSpec` by registry name (serializable) or
  instance, driving the planner's cost model;
- **planner knobs** — `InsertionOptions` / `ScheduleOptions`; ``None``
  insertion means the mode-appropriate default (`PAGED_INSERTION` for the
  offload modes — the old hard-coded ``min_bytes=1``);
- **transfer depth policy** — ``"auto"`` derives depth from the consumer's
  shape via `pool.auto_depth` (f(pages, layers)); an int pins it;
- **training memory policy** — remat mode and the optimizer-state offload
  toggle.

``to_dict``/``from_dict`` round-trip through plain JSON types, so a config
can live in a launch file and is diffable (`python -m repro.api
--print-config`).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Union

import jax.numpy as jnp

from repro.core.costmodel import ASCEND_LIKE, TPU_V5E, HardwareSpec
from repro.core.insertion import PAGED_INSERTION, InsertionOptions
from repro.core.schedule import ScheduleOptions
from repro.pool.topology import TierTopology
from repro.pool.transfer import auto_depth
from repro.slo.policy import SLOConfig

MODES = ("resident", "kv_offload", "paged", "continuous")
REMAT_MODES = ("none", "full", "offload")

#: Hardware specs addressable by name in a serialized config.
HW_SPECS: Dict[str, HardwareSpec] = {
    TPU_V5E.name: TPU_V5E,
    ASCEND_LIKE.name: ASCEND_LIKE,
}

#: modes whose KV tensors live in the pool (mandatory prefetches)
_OFFLOAD_MODES = ("kv_offload", "paged", "continuous")


@dataclass(frozen=True)
class PrefixCacheConfig:
    """Cross-request prefix cache knobs (``repro.prefix``): disabled by
    default; enabling requires chunked prefill (``chunk_size``) on a
    scheduler mode, since a prefix hit resumes prefill at the match
    offset."""

    enable: bool = False
    page_size: int = 16            # tokens per cached/shared KV page
    max_pages: Optional[int] = None   # cache footprint budget (None = ∞)
    min_match_pages: int = 1       # shortest match worth taking
    # tier pinning policy: the lowest pool tier a cached page may age down
    # to; a page the pool spills below this floor is invalidated (cheaper
    # to recompute than to fetch back). Validated against the session's
    # tier topology by OffloadConfig (the chain's names are declarative,
    # not fixed, so this block alone can't know them).
    pin_tier: str = "host"

    def __post_init__(self) -> None:
        if self.page_size < 1:
            raise ValueError("prefix_cache.page_size must be >= 1")
        if self.max_pages is not None and self.max_pages < 1:
            raise ValueError(
                "prefix_cache.max_pages must be >= 1 (or None = unbounded)")
        if self.min_match_pages < 1:
            raise ValueError("prefix_cache.min_match_pages must be >= 1")
        if not self.pin_tier or not isinstance(self.pin_tier, str):
            raise ValueError("prefix_cache.pin_tier must be a tier name")


@dataclass(frozen=True)
class KVCodecConfig:
    """Quantized KV page codec (``repro.pool.codec``): off by default
    (``codec="none"`` — pages move full precision, bit-identical serving).
    Enabling wraps every pool tier from ``below_tier`` down to the bottom
    of the chain in a ``CodecBackend``: pages quantize once on arrival
    below the boundary (per-page absmax scale stored alongside), every
    transfer across those links moves the 2–4× smaller payload, and
    admission counts the wrapped tiers at decoded-equivalent capacity.
    ``below_tier`` is validated against the session's tier topology by
    ``OffloadConfig`` (the chain's names are declarative, so this block
    alone can't know them)."""

    codec: str = "none"            # "none" | "int8" | "fp8"
    below_tier: str = "host"       # first (topmost) codec-wrapped tier

    def __post_init__(self) -> None:
        # late import: pool.codec pulls in jax; config stays light
        from repro.pool.codec import CODECS
        if self.codec not in CODECS:
            raise ValueError(
                f"kv_codec.codec {self.codec!r} not in {CODECS}")
        if not self.below_tier or not isinstance(self.below_tier, str):
            raise ValueError("kv_codec.below_tier must be a tier name")

    @property
    def enabled(self) -> bool:
        return self.codec != "none"


@dataclass(frozen=True)
class CalibrationConfig:
    """Closed-loop calibration knobs (``core.calibration``), applied by
    ``HyperOffloadSession.recalibrate()``: eligibility thresholds before a
    measured tier pair is trusted over the static spec (one tiny probe
    transfer is all fixed overhead — it would poison the bandwidth
    estimate), and the ceiling on how much in-flight transfer parallelism
    the loop may grow the engine to (the bandwidth-delay-product sizing is
    measured, but worker threads are a real resource)."""

    min_transfers: int = 2         # per-pair transfers before trusting it
    min_bytes: int = 1024          # per-pair bytes before trusting it
    max_inflight: int = 64         # ceiling for measured in-flight sizing

    def __post_init__(self) -> None:
        if self.min_transfers < 1:
            raise ValueError("calibration.min_transfers must be >= 1")
        if self.min_bytes < 0:
            raise ValueError("calibration.min_bytes must be >= 0")
        if self.max_inflight < 1:
            raise ValueError("calibration.max_inflight must be >= 1")


@dataclass(frozen=True)
class TelemetryConfig:
    """Unified telemetry knobs (``repro.obs``): disabled by default —
    the session then uses the shared no-op ``NULL_TRACER`` and serving
    behavior/output is unchanged. Enabling gives the session ONE
    structured `Tracer` (bounded ring of ``ring_capacity`` events) and
    per-request latency histograms, shared by every subsystem it hands
    out; ``trace_path`` (optional) writes the Chrome trace-event /
    Perfetto JSON file on ``session.close()``."""

    enable: bool = False
    ring_capacity: int = 65536     # bounded event ring (oldest drop first)
    trace_path: Optional[str] = None   # export on session close

    def __post_init__(self) -> None:
        if self.ring_capacity < 1:
            raise ValueError("telemetry.ring_capacity must be >= 1")


def _options_from(cls, d: Dict[str, Any]):
    """Rebuild a frozen options dataclass from a dict, restoring the tuple
    fields JSON flattened into lists. Unknown keys are a hard error — a
    typo in a launch file must not silently fall back to a default."""
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} fields: {sorted(unknown)}")
    return cls(**{k: tuple(v) if isinstance(v, list) else v
                  for k, v in d.items()})


@dataclass(frozen=True)
class OffloadConfig:
    """Frozen, serializable front-door configuration (see module doc)."""

    mode: str = "resident"

    # -- hardware + tier topology ---------------------------------------
    hw: Union[str, HardwareSpec] = TPU_V5E.name
    # either a full declarative chain...
    topology: Optional[TierTopology] = None
    # ...or the legacy per-tier capacities of the default chain (bytes;
    # None = unbounded). Mutually exclusive with an explicit topology.
    device_capacity: Optional[int] = None
    host_capacity: Optional[int] = None
    remote_capacity: Optional[int] = None
    # closed-loop calibration knobs (session.recalibrate())
    calibration: CalibrationConfig = field(default_factory=CalibrationConfig)

    # -- transfer depth policy ------------------------------------------
    transfer_depth: Union[str, int] = "auto"   # "auto" = f(pages, layers)
    transfer_workers: int = 2

    # -- serving geometry -----------------------------------------------
    max_seq: int = 128
    max_batch: int = 4
    prefill_budget: int = 1
    # chunked prefill (continuous/kv_offload scheduling): chunk_size sets
    # the tokens prefilled per scheduler step through one fixed compiled
    # shape; prefill_tokens is the per-step prefill *token* budget across
    # requests (None → one chunk). None chunk_size = whole-prompt prefill.
    chunk_size: Optional[int] = None
    prefill_tokens: Optional[int] = None
    page_size: int = 32
    cache_dtype: str = "float32"
    # cross-request prefix cache (scheduler modes with chunked prefill)
    prefix_cache: PrefixCacheConfig = field(default_factory=PrefixCacheConfig)
    # quantized KV page codec below a tier boundary (repro.pool.codec)
    kv_codec: KVCodecConfig = field(default_factory=KVCodecConfig)
    # unified telemetry (repro.obs): tracing + metrics, off by default
    telemetry: TelemetryConfig = field(default_factory=TelemetryConfig)
    # SLO-aware scheduling (repro.slo): priority classes, deadline-driven
    # preemption, goodput-maximizing admission; off by default (pure FIFO)
    slo: SLOConfig = field(default_factory=SLOConfig)

    # -- planner knobs --------------------------------------------------
    insertion: Optional[InsertionOptions] = None   # None → mode default
    schedule: ScheduleOptions = field(default_factory=ScheduleOptions)
    refine: bool = True

    # -- training memory policy -----------------------------------------
    remat: str = "none"
    offload_opt_state: bool = False
    host_memory_kind: Optional[str] = None   # None = probe the platform

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.remat not in REMAT_MODES:
            raise ValueError(f"remat {self.remat!r} not in {REMAT_MODES}")
        if isinstance(self.hw, str) and self.hw not in HW_SPECS:
            raise ValueError(
                f"unknown hardware {self.hw!r}; have {sorted(HW_SPECS)} "
                "(or pass a HardwareSpec instance)")
        if not (self.transfer_depth == "auto"
                or (isinstance(self.transfer_depth, int)
                    and self.transfer_depth >= 1)):
            raise ValueError(
                f"transfer_depth must be 'auto' or an int >= 1, "
                f"got {self.transfer_depth!r}")
        if self.chunk_size is not None and not (
                1 <= self.chunk_size <= self.max_seq):
            raise ValueError(
                f"chunk_size {self.chunk_size} must be in [1, max_seq="
                f"{self.max_seq}]")
        if self.prefill_tokens is not None:
            if self.chunk_size is None:
                raise ValueError(
                    "prefill_tokens (a per-step prefill token budget) "
                    "requires chunk_size")
            if self.prefill_tokens < 1:
                raise ValueError("prefill_tokens must be >= 1")
        if self.prefix_cache.enable:
            if self.chunk_size is None:
                raise ValueError(
                    "prefix_cache.enable requires chunk_size (a prefix hit "
                    "resumes prefill at the match offset, which only the "
                    "chunked path supports)")
            if self.mode not in ("continuous", "kv_offload"):
                raise ValueError(
                    "prefix_cache.enable requires a scheduler mode "
                    "('continuous' or 'kv_offload'), "
                    f"got mode={self.mode!r}")
        if self.slo.enable and self.mode not in ("continuous", "kv_offload"):
            raise ValueError(
                "slo.enable requires a scheduler mode ('continuous' or "
                f"'kv_offload'), got mode={self.mode!r}")
        if self.topology is not None:
            if not isinstance(self.topology, TierTopology):
                raise ValueError(
                    "topology must be a TierTopology (build one with "
                    "TierTopology(tiers=(TierSpec(...), ...)))")
            if any(c is not None for c in (self.device_capacity,
                                           self.host_capacity,
                                           self.remote_capacity)):
                raise ValueError(
                    "pass tier capacities inside the topology's TierSpecs, "
                    "not alongside an explicit topology")
        # only an *enabled* prefix cache must name a real tier — the
        # default pin ("host") shouldn't invalidate every custom chain
        if self.prefix_cache.enable:
            names = self.tier_topology.names
            if self.prefix_cache.pin_tier not in names:
                raise ValueError(
                    f"prefix_cache.pin_tier {self.prefix_cache.pin_tier!r} "
                    f"not a tier of the topology {names}")
        # same deal for the codec boundary: only an enabled codec must
        # name a real, off-accelerator tier of the effective chain
        if self.kv_codec.enabled:
            topo = self.tier_topology
            if self.kv_codec.below_tier not in topo.names:
                raise ValueError(
                    f"kv_codec.below_tier {self.kv_codec.below_tier!r} "
                    f"not a tier of the topology {topo.names}")
            spec = next(t for t in topo.tiers
                        if t.name == self.kv_codec.below_tier)
            if spec.kind == "device":
                raise ValueError(
                    f"kv_codec.below_tier {self.kv_codec.below_tier!r} is "
                    "an accelerator tier; the compute path needs "
                    "full-precision pages on device — pick an "
                    "off-accelerator tier")

    # ------------------------------------------------------------------
    @property
    def hardware(self) -> HardwareSpec:
        return HW_SPECS[self.hw] if isinstance(self.hw, str) else self.hw

    @property
    def tier_topology(self) -> TierTopology:
        """The effective chain: the explicit topology, else the default
        device/host/remote chain under the legacy capacity fields."""
        if self.topology is not None:
            return self.topology
        return TierTopology.default(device_capacity=self.device_capacity,
                                    host_capacity=self.host_capacity,
                                    remote_capacity=self.remote_capacity)

    @property
    def offload_kv(self) -> bool:
        """Does this mode park KV state in the pool between steps?"""
        return self.mode == "kv_offload"

    @property
    def dtype(self):
        return jnp.dtype(self.cache_dtype)

    def insertion_options(self) -> InsertionOptions:
        """Explicit options, else the mode default: offload modes plan every
        pool-resident KV tensor (`PAGED_INSERTION`, the documented old
        ``min_bytes=1``); resident keeps the cost-model thresholds."""
        if self.insertion is not None:
            return self.insertion
        return PAGED_INSERTION if self.mode in _OFFLOAD_MODES \
            else InsertionOptions()

    def depth_for(self, *, layers: Optional[int] = None,
                  pages: Optional[int] = None) -> int:
        """Resolve the transfer depth for a consumer of the given shape."""
        if self.transfer_depth == "auto":
            return auto_depth(layers=layers, pages=pages)
        return int(self.transfer_depth)

    # -- serialization --------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Plain-JSON-types dict; ``from_dict`` inverts it exactly."""
        d = dataclasses.asdict(self)
        hw = self.hw
        if isinstance(hw, HardwareSpec):
            # a registered spec serializes by name; a custom one by fields
            if HW_SPECS.get(hw.name) == hw:
                d["hw"] = hw.name
            else:
                d["hw"] = dataclasses.asdict(hw)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "OffloadConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown OffloadConfig fields: {sorted(unknown)}")
        kwargs = dict(d)
        hw = kwargs.get("hw")
        if isinstance(hw, dict):
            kwargs["hw"] = HardwareSpec(**hw)
        if isinstance(kwargs.get("topology"), dict):
            kwargs["topology"] = TierTopology.from_dict(kwargs["topology"])
        if isinstance(kwargs.get("calibration"), dict):
            kwargs["calibration"] = _options_from(CalibrationConfig,
                                                  kwargs["calibration"])
        if isinstance(kwargs.get("insertion"), dict):
            kwargs["insertion"] = _options_from(InsertionOptions,
                                                kwargs["insertion"])
        if isinstance(kwargs.get("schedule"), dict):
            kwargs["schedule"] = _options_from(ScheduleOptions,
                                               kwargs["schedule"])
        if isinstance(kwargs.get("prefix_cache"), dict):
            kwargs["prefix_cache"] = _options_from(PrefixCacheConfig,
                                                   kwargs["prefix_cache"])
        if isinstance(kwargs.get("kv_codec"), dict):
            kwargs["kv_codec"] = _options_from(KVCodecConfig,
                                               kwargs["kv_codec"])
        if isinstance(kwargs.get("telemetry"), dict):
            kwargs["telemetry"] = _options_from(TelemetryConfig,
                                                kwargs["telemetry"])
        if isinstance(kwargs.get("slo"), dict):
            kwargs["slo"] = _options_from(SLOConfig, kwargs["slo"])
        return cls(**kwargs)

    def replace(self, **changes) -> "OffloadConfig":
        return dataclasses.replace(self, **changes)
