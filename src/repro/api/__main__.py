"""Config introspection CLI.

    PYTHONPATH=src python -m repro.api --print-config [--mode MODE]

Dumps the (default) `OffloadConfig` as sorted JSON. `scripts/ci.sh` writes
it to ``CONFIG_default.json`` so any drift in the public config surface —
a new field, a changed default — shows up in review diffs.
"""

from __future__ import annotations

import argparse
import json

from repro.api.config import MODES, OffloadConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="HyperOffload public-API introspection")
    ap.add_argument("--print-config", action="store_true",
                    help="dump the default OffloadConfig as JSON")
    ap.add_argument("--mode", choices=MODES, default=None,
                    help="dump the defaults for this mode instead")
    args = ap.parse_args(argv)
    if not args.print_config:
        ap.print_help()
        return 2
    cfg = OffloadConfig() if args.mode is None else OffloadConfig(mode=args.mode)
    d = cfg.to_dict()
    # the effective (mode-resolved) planner default is part of the surface
    d["insertion_resolved"] = cfg.insertion_options().__dict__
    # likewise the effective tier chain (explicit topology, or the default
    # three-tier chain built from the capacity fields)
    d["topology_resolved"] = cfg.tier_topology.to_dict()
    print(json.dumps(d, indent=2, sort_keys=True, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
