"""Fragmentation-aware device-allocator simulator.

The paper's Table 4 attributes the baseline's long-sequence slowdown to
memory *defragmentation events* (57 → 0 with hierarchical memory). We model
the device allocator as a first-fit free-list over a fixed HBM address
space: allocations at tensor birth, frees at death. When a request fails
although total free bytes suffice (external fragmentation), the allocator
performs a *compaction* — one defragmentation event with a cost proportional
to the live bytes moved. Replaying the same op trace with HyperOffload's
offloading (smaller residency) eliminates the failures, reproducing the
57→0 behaviour qualitatively and its latency consequence quantitatively.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class AllocStats:
    defrag_events: int = 0
    oom_events: int = 0
    bytes_moved: int = 0            # total live bytes copied during compactions
    high_water: int = 0


class FirstFitAllocator:
    """First-fit free-list allocator with compaction on fragmentation."""

    def __init__(self, capacity: int, alignment: int = 512) -> None:
        self.capacity = int(capacity)
        self.alignment = alignment
        self.blocks: Dict[str, Tuple[int, int]] = {}   # name -> (offset, size)
        self.stats = AllocStats()

    # ------------------------------------------------------------------
    def _aligned(self, size: int) -> int:
        a = self.alignment
        return -(-size // a) * a

    def _free_intervals(self) -> List[Tuple[int, int]]:
        """Sorted (offset, size) free gaps."""
        used = sorted(self.blocks.values())
        gaps: List[Tuple[int, int]] = []
        cur = 0
        for off, size in used:
            if off > cur:
                gaps.append((cur, off - cur))
            cur = max(cur, off + size)
        if cur < self.capacity:
            gaps.append((cur, self.capacity - cur))
        return gaps

    def free_bytes(self) -> int:
        return self.capacity - sum(s for _, s in self.blocks.values())

    def live_bytes(self) -> int:
        return sum(s for _, s in self.blocks.values())

    # ------------------------------------------------------------------
    def alloc(self, name: str, size: int) -> bool:
        """Returns True on success; counts defrag/OOM events internally."""
        if name in self.blocks:
            raise ValueError(f"double alloc of {name}")
        size = self._aligned(size)
        if size == 0:
            self.blocks[name] = (0, 0)
            return True
        for off, gap in self._free_intervals():
            if gap >= size:
                self.blocks[name] = (off, size)
                self.stats.high_water = max(self.stats.high_water, self.live_bytes())
                return True
        # no contiguous gap — fragmentation or true OOM?
        if self.free_bytes() >= size:
            self._compact()
            self.stats.defrag_events += 1
            return self.alloc_after_compact(name, size)
        self.stats.oom_events += 1
        return False

    def alloc_after_compact(self, name: str, size: int) -> bool:
        for off, gap in self._free_intervals():
            if gap >= size:
                self.blocks[name] = (off, size)
                self.stats.high_water = max(self.stats.high_water, self.live_bytes())
                return True
        self.stats.oom_events += 1
        return False

    def _compact(self) -> None:
        cur = 0
        for name in sorted(self.blocks, key=lambda n: self.blocks[n][0]):
            off, size = self.blocks[name]
            if off != cur:
                self.stats.bytes_moved += size
            self.blocks[name] = (cur, size)
            cur += size

    def free(self, name: str) -> None:
        self.blocks.pop(name, None)


def replay(events: Sequence[Tuple[int, str, str]],
           sizes: Dict[str, int], capacity: int,
           alignment: int = 512) -> AllocStats:
    """Replay a memsim event trace ((pos, 'alloc'|'free', tensor)) through
    the allocator and return fragmentation statistics."""
    a = FirstFitAllocator(capacity, alignment)
    for _, op, tensor in events:
        if op == "alloc":
            a.alloc(tensor, sizes[tensor])
        else:
            a.free(tensor)
    return a.stats
