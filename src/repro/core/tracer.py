"""ModelConfig → layer-level IR graphs for training / prefill / decode.

Analytic per-layer FLOP and byte counts feed the cost model; tensor classes
mark what HyperOffload may move (activations, optimizer states, KV blocks).
Sizes and FLOPs are *per device*: pass ``shards`` to divide the global
workload across the mesh.

Simplifications (documented):
- weights are updated in place by the optimizer node (no SSA weight chain);
- per-layer saved activations are a dimension-aware aggregate
  (residual + qkv + ffn intermediates), not an op-exact list;
- decode may read only a fraction of each layer's KV (``kv_read_fraction``)
  to model sparse-attention block selection (the paper's DeepSeek+NSA
  setting, §7.3).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.ir import Graph


# ---------------------------------------------------------------------------
# Analytic per-layer quantities
# ---------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, spec: LayerSpec, active: bool = False) -> int:
    n = cfg._mixer_params(spec) + cfg._norm_params(spec)
    if spec.ffn == "moe":
        m = cfg.moe
        experts = m.top_k if active else m.n_experts
        n += cfg.d_model * m.n_experts + experts * 3 * cfg.d_model * m.d_ff_expert
    else:
        n += cfg._ffn_params(spec)
    return n


def attn_flops(cfg: ModelConfig, spec: LayerSpec, batch: int, q_len: int,
               kv_len: int) -> float:
    """QK^T + PV flops for one layer (causal averaged when q_len == kv_len)."""
    if spec.mixer == "mamba2":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        # SSD: intra-chunk quadratic + state update/readout
        intra = 2.0 * batch * q_len * min(s.chunk_size, q_len) * di
        state = 4.0 * batch * q_len * di * s.d_state
        return intra + state
    window = spec.window
    eff = kv_len if window is None else min(window, kv_len)
    causal = 0.5 if (q_len == kv_len and window is None) else 1.0
    hd = cfg.head_dim if spec.mixer == "attn" else (
        cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim)
    return 4.0 * batch * q_len * eff * cfg.n_heads * hd * causal


def layer_fwd_flops(cfg: ModelConfig, spec: LayerSpec, batch: int,
                    q_len: int, kv_len: Optional[int] = None) -> float:
    kv_len = q_len if kv_len is None else kv_len
    tokens = batch * q_len
    return 2.0 * layer_params(cfg, spec, active=True) * tokens + attn_flops(
        cfg, spec, batch, q_len, kv_len)


def saved_act_bytes(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                    dtype_bytes: int = 2) -> int:
    """Dimension-aware aggregate of activations saved for backward."""
    d = cfg.d_model
    if spec.mixer == "mamba2":
        inner = 2 * cfg.ssm.d_inner(d)
    elif spec.ffn == "moe":
        inner = cfg.q_dim + 2 * cfg.n_kv_heads * cfg.head_dim + 2 * cfg.moe.top_k * cfg.moe.d_ff_expert
    elif spec.mixer == "mla":
        m = cfg.mla
        inner = cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim + m.v_head_dim) + 2 * cfg.d_ff
    else:
        inner = cfg.q_dim + 2 * cfg.n_kv_heads * cfg.head_dim + 2 * cfg.d_ff
    return int(batch * seq * (2 * d + inner) * dtype_bytes)


def kv_bytes_layer(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                   dtype_bytes: int = 2) -> int:
    if spec.mixer == "mamba2":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        conv = (di + 2 * s.n_groups * s.d_state) * (s.d_conv - 1)
        state = s.n_ssm_heads(cfg.d_model) * s.headdim * s.d_state * 4
        return int(batch * (conv * dtype_bytes + state))
    eff = seq if spec.window is None else min(spec.window, seq)
    if spec.mixer == "mla":
        m = cfg.mla
        return int(batch * eff * (m.kv_lora_rank + m.qk_rope_head_dim) * dtype_bytes)
    return int(2 * batch * eff * cfg.n_kv_heads * cfg.head_dim * dtype_bytes)


# ---------------------------------------------------------------------------
# Graph builders
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceOptions:
    dtype_bytes: int = 2          # bf16 compute/activations/KV
    shards: int = 1               # devices sharing the global workload
    remote_opt_states: bool = True
    remote_kv: bool = True
    kv_read_fraction: float = 1.0
    grad_dtype_bytes: int = 2
    # weight precision may differ (e.g. INT4-quantized serving: 0.5)
    weight_dtype_bytes: Optional[float] = None

    @property
    def w_bytes(self) -> float:
        return self.weight_dtype_bytes if self.weight_dtype_bytes is not None \
            else float(self.dtype_bytes)


def trace_train_step(cfg: ModelConfig, batch: int, seq: int,
                     opts: TraceOptions = TraceOptions(),
                     recompute_layers: Optional[frozenset] = None) -> Graph:
    """``recompute_layers``: layer indices using activation recomputation —
    they save only the layer input (B·S·D) and pay an extra forward in the
    backward pass (the paper's baseline memory-saving technique, §7.1)."""
    g = Graph()
    sh = opts.shards
    specs = cfg.layer_specs()
    d = cfg.d_model
    hidden = int(batch * seq * d * opts.dtype_bytes / sh)
    loc_state = "remote" if opts.remote_opt_states else "device"
    recompute_layers = recompute_layers or frozenset()

    emb_bytes = int(cfg.vocab_size * d * opts.w_bytes / sh)
    g.add_tensor("w_embed", emb_bytes, "weight")
    g.add_tensor("h_embed", hidden)
    g.compute("fwd_embed", inputs=("w_embed",), outputs=("h_embed",),
              flops=2.0 * batch * seq * d / sh, hbm_bytes=emb_bytes + hidden)

    prev_h = "h_embed"
    for i, spec in enumerate(specs):
        wb = int(layer_params(cfg, spec) * opts.w_bytes / sh)
        if i in recompute_layers:
            ab = hidden  # only the layer input is saved
        else:
            ab = int(saved_act_bytes(cfg, spec, batch, seq, opts.dtype_bytes) / sh)
        g.add_tensor(f"w_{i}", wb, "weight")
        g.add_tensor(f"act_{i}", ab)
        g.add_tensor(f"h_{i}", hidden)
        g.add_tensor(f"m_{i}", int(layer_params(cfg, spec) * 4 / sh), "state", loc_state)
        g.add_tensor(f"v_{i}", int(layer_params(cfg, spec) * 4 / sh), "state", loc_state)
        fl = layer_fwd_flops(cfg, spec, batch, seq) / sh
        g.compute(f"fwd_{i}", inputs=(prev_h, f"w_{i}"),
                  outputs=(f"act_{i}", f"h_{i}"),
                  flops=fl, hbm_bytes=wb + 2 * hidden + ab)
        prev_h = f"h_{i}"

    g.add_tensor("loss_grad", hidden)
    lf = 2.0 * batch * seq * d * cfg.vocab_size / sh
    g.compute("loss", inputs=(prev_h, "w_embed"), outputs=("loss_grad",),
              flops=2 * lf, hbm_bytes=emb_bytes + 2 * hidden)

    prev_g = "loss_grad"
    for i in reversed(range(len(specs))):
        spec = specs[i]
        wb = g.tensors[f"w_{i}"].nbytes
        gb = int(layer_params(cfg, spec) * opts.grad_dtype_bytes / sh)
        g.add_tensor(f"grad_{i}", gb)
        g.add_tensor(f"gh_{i}", hidden)
        bwd_factor = 3.0 if i in recompute_layers else 2.0  # recompute pays +1 fwd
        fl = bwd_factor * layer_fwd_flops(cfg, spec, batch, seq) / sh
        g.compute(f"bwd_{i}", inputs=(prev_g, f"act_{i}", f"w_{i}"),
                  outputs=(f"grad_{i}", f"gh_{i}"),
                  flops=fl, hbm_bytes=wb + gb + 2 * hidden +
                  g.tensors[f"act_{i}"].nbytes)
        prev_g = f"gh_{i}"

    for i, spec in enumerate(specs):
        p = layer_params(cfg, spec) / sh
        g.add_tensor(f"m_new_{i}", g.tensors[f"m_{i}"].nbytes, "state")
        g.add_tensor(f"v_new_{i}", g.tensors[f"v_{i}"].nbytes, "state")
        g.compute(f"opt_{i}",
                  inputs=(f"grad_{i}", f"m_{i}", f"v_{i}", f"w_{i}"),
                  outputs=(f"m_new_{i}", f"v_new_{i}"),
                  flops=12.0 * p,
                  hbm_bytes=g.tensors[f"m_{i}"].nbytes * 4)
    return g


def trace_prefill(cfg: ModelConfig, batch: int, seq: int,
                  opts: TraceOptions = TraceOptions()) -> Graph:
    g = Graph()
    sh = opts.shards
    specs = cfg.layer_specs()
    d = cfg.d_model
    hidden = int(batch * seq * d * opts.dtype_bytes / sh)
    emb_bytes = int(cfg.vocab_size * d * opts.w_bytes / sh)
    g.add_tensor("w_embed", emb_bytes, "weight")
    g.add_tensor("h_embed", hidden)
    g.compute("embed", inputs=("w_embed",), outputs=("h_embed",),
              flops=2.0 * batch * seq * d / sh, hbm_bytes=emb_bytes + hidden)
    prev_h = "h_embed"
    for i, spec in enumerate(specs):
        wb = int(layer_params(cfg, spec) * opts.w_bytes / sh)
        kb = int(kv_bytes_layer(cfg, spec, batch, seq, opts.dtype_bytes) / sh)
        g.add_tensor(f"w_{i}", wb, "weight")
        g.add_tensor(f"h_{i}", hidden)
        g.add_tensor(f"kv_{i}", kb, "state")  # produced, then parked if remote_kv
        # sparse attention (NSA): each query attends a fraction of the keys
        eff_kv = max(1, int(seq * opts.kv_read_fraction))
        fl = layer_fwd_flops(cfg, spec, batch, seq, kv_len=eff_kv) / sh
        g.compute(f"fwd_{i}", inputs=(prev_h, f"w_{i}"),
                  outputs=(f"h_{i}", f"kv_{i}"),
                  flops=fl, hbm_bytes=wb + 2 * hidden + kb)
        prev_h = f"h_{i}"
    g.add_tensor("logits", int(batch * cfg.vocab_size * 4 / sh))
    g.compute("lm_head", inputs=(prev_h, "w_embed"), outputs=("logits",),
              flops=2.0 * batch * d * cfg.vocab_size / sh,
              hbm_bytes=emb_bytes + hidden)
    return g


def trace_decode_step(cfg: ModelConfig, batch: int, ctx_len: int,
                      opts: TraceOptions = TraceOptions()) -> Graph:
    g = Graph()
    sh = opts.shards
    specs = cfg.layer_specs()
    d = cfg.d_model
    hidden = int(batch * d * opts.dtype_bytes / sh)
    loc_kv = "remote" if opts.remote_kv else "device"
    emb_bytes = int(cfg.vocab_size * d * opts.w_bytes / sh)
    g.add_tensor("w_embed", emb_bytes, "weight")
    g.add_tensor("h_embed", hidden)
    g.compute("embed", inputs=("w_embed",), outputs=("h_embed",),
              flops=2.0 * batch * d / sh, hbm_bytes=emb_bytes // max(1, 1) + hidden)
    prev_h = "h_embed"
    for i, spec in enumerate(specs):
        wb = int(layer_params(cfg, spec) * opts.w_bytes / sh)
        kb_full = int(kv_bytes_layer(cfg, spec, batch, ctx_len, opts.dtype_bytes) / sh)
        kb_read = int(kb_full * opts.kv_read_fraction)
        g.add_tensor(f"w_{i}", wb, "weight")
        g.add_tensor(f"h_{i}", hidden)
        # resident baseline: the FULL cache lives on device; offloaded: only
        # the sparse-selected blocks are materialized (fetched from the pool)
        kv_bytes = max(kb_read, 1) if opts.remote_kv else kb_full
        g.add_tensor(f"kv_{i}", kv_bytes, "state", loc_kv)
        fl = layer_fwd_flops(cfg, spec, batch, 1, kv_len=int(ctx_len * opts.kv_read_fraction)) / sh
        g.compute(f"dec_{i}", inputs=(prev_h, f"w_{i}", f"kv_{i}"),
                  outputs=(f"h_{i}",),
                  flops=fl, hbm_bytes=wb + kb_read + 2 * hidden)
        prev_h = f"h_{i}"
    g.add_tensor("logits", int(batch * cfg.vocab_size * 4 / sh))
    g.compute("lm_head", inputs=(prev_h, "w_embed"), outputs=("logits",),
              flops=2.0 * batch * d * cfg.vocab_size / sh,
              hbm_bytes=emb_bytes + hidden)
    return g
