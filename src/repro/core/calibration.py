"""Closed-loop calibration: measured transfer telemetry → planner inputs.

The planner's transfer estimates come from a static ``HardwareSpec`` —
numbers typed in from datasheets. But the runtime *measures* every byte
the hierarchy actually moves: the transfer engine's per tier-pair table
(``TransferStats.pairs``) accumulates {transfers, bytes, busy_s} for each
``src->dst`` link, where busy_s is summed per-transfer execution time.
This module closes the loop the paper's global-planning argument implies:

1. ``measurements_from_pairs`` lifts the raw table into
   ``TierPairMeasurement``s;
2. ``calibrate`` folds them into a ``CalibratedHardwareSpec`` — the same
   planner interface (``transfer_time`` etc.), but with pool bandwidths
   replaced by byte-weighted *measured* bandwidth per direction, plus the
   full per-pair table for N-tier topologies;
3. ``required_inflight`` sizes prefetch parallelism to the measured
   bandwidth-delay product: on a latency-dominated tier, completing a
   step's worth of fetches inside the overlap window needs
   ``pages × mean_transfer_time / window`` transfers genuinely in flight.

``HyperOffloadSession.recalibrate()`` drives all three: replan with the
calibrated spec, grow the engine to the required parallelism.

Thin-data guards: pairs with fewer than ``min_transfers`` transfers or
``min_bytes`` total bytes are ignored (a single tiny probe transfer is
dominated by fixed overheads and would poison the bandwidth estimate);
with no eligible measurement in a direction, the static number survives.
"""

from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import Dict, Mapping, Optional, Tuple

from repro.core.costmodel import HardwareSpec

#: default eligibility thresholds (mirrored by ``api.CalibrationConfig``)
MIN_TRANSFERS = 2
MIN_BYTES = 1024


@dataclass(frozen=True)
class TierPairMeasurement:
    """Aggregated measured movement over one directed tier pair."""

    src: str
    dst: str
    transfers: int
    nbytes: int
    busy_s: float

    @property
    def bandwidth(self) -> float:
        """Per-stream measured bytes/s (busy time double-counts concurrent
        transfers, so this is the single-transfer rate a planner's
        ``transfer_time`` estimate should match)."""
        return self.nbytes / self.busy_s if self.busy_s > 0 else 0.0

    @property
    def mean_transfer_s(self) -> float:
        return self.busy_s / self.transfers if self.transfers else 0.0


def measurements_from_pairs(
        pairs: Mapping[str, Mapping[str, float]],
) -> Dict[Tuple[str, str], TierPairMeasurement]:
    """Parse ``TransferStats.pairs`` (keys ``"src->dst"``) into typed
    measurements keyed by the (src, dst) tuple."""
    out: Dict[Tuple[str, str], TierPairMeasurement] = {}
    for key, b in pairs.items():
        src, sep, dst = key.partition("->")
        if not sep or not src or not dst:
            raise ValueError(f"malformed tier-pair key {key!r}")
        out[(src, dst)] = TierPairMeasurement(
            src=src, dst=dst, transfers=int(b["transfers"]),
            nbytes=int(b["bytes"]), busy_s=float(b["busy_s"]))
    return out


def _eligible(m: TierPairMeasurement, min_transfers: int,
              min_bytes: int) -> bool:
    return (m.transfers >= min_transfers and m.nbytes >= min_bytes
            and m.busy_s > 0)


@dataclass(frozen=True)
class CalibratedHardwareSpec(HardwareSpec):
    """A ``HardwareSpec`` whose pool bandwidths are measured, not assumed.

    Drop-in for the planner (same ``transfer_time`` interface, now backed
    by measured numbers); carries the full per-pair bandwidth table for
    N-tier topologies where a single d2r/r2d scalar can't express every
    link. The name is suffixed ``+measured`` so plan caches keyed on
    ``hw.name`` never alias a calibrated plan with a static one."""

    pair_bw: Tuple[Tuple[str, str, float], ...] = ()

    def bandwidth_between(self, src: str, dst: str) -> Optional[float]:
        """Measured bytes/s over one directed link (None = not measured)."""
        for s, d, bw in self.pair_bw:
            if s == src and d == dst:
                return bw
        return None


def calibrate(base: HardwareSpec,
              measurements: Mapping[Tuple[str, str], TierPairMeasurement], *,
              device_tier: str = "device",
              min_transfers: int = MIN_TRANSFERS,
              min_bytes: int = MIN_BYTES) -> CalibratedHardwareSpec:
    """Fold measured per-pair bandwidth into a planner spec.

    Every eligible pair lands in ``pair_bw``; the scalar pool bandwidths
    the cost model consumes aggregate byte-weighted across pairs touching
    ``device_tier`` — reads into it set ``pool_bw_r2d``, writes out of it
    set ``pool_bw_d2r``. Directions with no eligible data keep ``base``'s
    static numbers."""
    eligible = {k: m for k, m in measurements.items()
                if _eligible(m, min_transfers, min_bytes)}

    def weighted_bw(ms) -> Optional[float]:
        total_bytes = sum(m.nbytes for m in ms)
        total_busy = sum(m.busy_s for m in ms)
        return total_bytes / total_busy if total_busy > 0 else None

    r2d = weighted_bw([m for (s, d), m in eligible.items()
                       if d == device_tier and s != device_tier])
    d2r = weighted_bw([m for (s, d), m in eligible.items()
                       if s == device_tier and d != device_tier])
    fields = asdict(base)
    fields.pop("pair_bw", None)   # re-calibrating an already-calibrated spec
    fields["name"] = f"{base.name.split('+measured')[0]}+measured"
    if r2d is not None:
        fields["pool_bw_r2d"] = r2d
    if d2r is not None:
        fields["pool_bw_d2r"] = d2r
    pair_bw = tuple(sorted((m.src, m.dst, m.bandwidth)
                           for m in eligible.values()))
    return CalibratedHardwareSpec(pair_bw=pair_bw, **fields)


def required_inflight(
        measurements: Mapping[Tuple[str, str], TierPairMeasurement], *,
        pages_per_step: float, window_s: float,
        device_tier: str = "device", cap: int = 64,
        min_transfers: int = MIN_TRANSFERS,
        min_bytes: int = MIN_BYTES) -> int:
    """In-flight transfer parallelism needed to complete one step's
    fetches inside the overlap window — the measured bandwidth-delay
    product. The window is clamped below at one mean transfer time:
    transfers can't be spread thinner than one of themselves, so a
    window at or under ``mean_t`` degrades to the latency-dominated
    answer — every one of the step's fetches genuinely concurrent
    (``ceil(pages_per_step)``). Returns 0 when there is no evidence
    (no eligible read pair, or a degenerate window): callers leave the
    engine alone."""
    if pages_per_step <= 0 or window_s <= 0:
        return 0
    reads = [m for (s, d), m in measurements.items()
             if d == device_tier and s != device_tier
             and _eligible(m, min_transfers, min_bytes)]
    total_transfers = sum(m.transfers for m in reads)
    if not total_transfers:
        return 0
    mean_t = sum(m.busy_s for m in reads) / total_transfers
    need = math.ceil(pages_per_step * mean_t / max(window_s, mean_t))
    return max(1, min(int(need), int(cap)))
