"""End-to-end HyperOffload planning pipeline.

``HyperOffloadPlanner.plan(graph)`` = insertion (§4.2.2) → Algorithm 1
execution-order refinement (§4.3) → timeline + memory evaluation, returning
an ``OffloadPlan`` carrying both the optimized artifacts and the baselines
(resident-everything and reactive-runtime) the paper compares against.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import allocator, insertion, memsim, schedule, timeline
from repro.core.costmodel import HardwareSpec
from repro.core.ir import Graph


@dataclass
class OffloadPlan:
    graph: Graph                     # graph with cache operators
    order: List[str]                 # refined execution order
    timeline: timeline.Timeline      # optimized timeline
    memory: memsim.MemoryTrace       # optimized memory trace
    base_timeline: timeline.Timeline # no offloading, everything resident
    base_memory: memsim.MemoryTrace
    naive_timeline: Optional[timeline.Timeline] = None  # unrefined cache-op order
    naive_memory: Optional[memsim.MemoryTrace] = None
    reactive_timeline: Optional[timeline.Timeline] = None

    # ------------------------------------------------------------------
    @property
    def peak_reduction(self) -> float:
        b = self.base_memory.peak_bytes
        return 0.0 if b == 0 else 1.0 - self.memory.peak_bytes / b

    @property
    def slowdown(self) -> float:
        b = self.base_timeline.total
        return 0.0 if b == 0 else self.timeline.total / b - 1.0

    def summary(self) -> Dict[str, float]:
        return {
            "base_peak_gb": self.base_memory.peak_bytes / 1e9,
            "opt_peak_gb": self.memory.peak_bytes / 1e9,
            "peak_reduction": self.peak_reduction,
            "base_step_s": self.base_timeline.total,
            "opt_step_s": self.timeline.total,
            "exposed_comm_s": self.timeline.exposed_comm,
            "slowdown": self.slowdown,
        }


class HyperOffloadPlanner:
    def __init__(self, hw: HardwareSpec,
                 insert_opts: insertion.InsertionOptions = insertion.InsertionOptions(),
                 sched_opts: schedule.ScheduleOptions = schedule.ScheduleOptions(),
                 reactive_capacity: Optional[float] = None) -> None:
        self.hw = hw
        self.insert_opts = insert_opts
        self.sched_opts = sched_opts
        self.reactive_capacity = reactive_capacity

    def with_hardware(self, hw: HardwareSpec) -> "HyperOffloadPlanner":
        """The same planning policy under a different hardware model — the
        calibration loop swaps in a ``CalibratedHardwareSpec`` this way so
        every subsequent plan's transfer estimates are measured, not
        assumed."""
        return HyperOffloadPlanner(hw, insert_opts=self.insert_opts,
                                   sched_opts=self.sched_opts,
                                   reactive_capacity=self.reactive_capacity)

    def plan(self, graph: Graph, refine: bool = True) -> OffloadPlan:
        base = graph.residentize()
        base_tl = timeline.simulate(base, self.hw)
        base_mem = memsim.simulate(base)

        g = insertion.insert_cache_ops(graph, self.hw, self.insert_opts)
        naive_order = g.order()
        naive_tl = timeline.simulate(g, self.hw, naive_order)
        naive_mem = memsim.simulate(g, naive_order)

        order = (schedule.refine_order(g, self.hw, naive_order, self.sched_opts)
                 if refine else naive_order)
        tl = timeline.simulate(g, self.hw, order)
        mem = memsim.simulate(g, order)

        reactive_tl = None
        if self.reactive_capacity is not None:
            reactive_tl = timeline.simulate_reactive(
                base, self.hw, self.reactive_capacity)

        return OffloadPlan(
            graph=g, order=order, timeline=tl, memory=mem,
            base_timeline=base_tl, base_memory=base_mem,
            naive_timeline=naive_tl, naive_memory=naive_mem,
            reactive_timeline=reactive_tl,
        )
