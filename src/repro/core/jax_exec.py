"""Execute an offload plan on real JAX arrays.

Lowers the IR's cache operators to genuine JAX memory-kind transfers:
``prefetch`` = ``jax.device_put(host_copy, device-memory sharding)``,
``store`` = ``jax.device_put(x, pinned_host sharding)``, ``detach`` = drop
the device reference. Compute nodes bind to user-supplied callables. The
executor asserts the same IR legality rules the simulator uses, so a plan
that validates in the compiler also runs — and produces values identical to
the everything-resident baseline (tests/test_jax_exec.py).

XLA dispatches ``device_put`` asynchronously; on real TPU hardware the
transfer engines run under compute exactly as the timeline simulator
models. On the CPU test backend the memory kinds exist but transfers are
synchronous copies — correctness is what we validate here, overlap is what
the simulator + dry-run quantify.
"""

from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Sequence

import jax

from repro.core.ir import Graph
from repro.pool import backend as pool_backend


class PlanExecutor:
    def __init__(self, graph: Graph,
                 compute_fns: Mapping[str, Callable],
                 device: Optional[jax.Device] = None) -> None:
        self.graph = graph
        self.fns = dict(compute_fns)
        self.device = device or jax.devices()[0]
        self.dev_sharding = pool_backend.device_sharding(self.device)
        # probed host kind; None → NumPy host buffers (pool.backend fallback)
        self.host_sharding = pool_backend.host_sharding(self.device)
        missing = [n for n, node in graph.nodes.items()
                   if node.kind == "compute" and n not in self.fns]
        if missing:
            raise ValueError(f"no compute fn bound for {missing}")

    def run(self, inputs: Mapping[str, jax.Array],
            order: Optional[Sequence[str]] = None) -> Dict[str, jax.Array]:
        """``inputs`` must provide every tensor with no producer (weights,
        states, graph inputs). Returns the final environment (device-resident
        tensors) plus host-parked tensors under their names."""
        graph = self.graph
        order = list(order) if order is not None else graph.order()
        graph.validate_order(order)

        def to_host(x):
            if self.host_sharding is None:
                return pool_backend.to_host(x, self.device)
            return jax.device_put(x, self.host_sharding)

        env: Dict[str, jax.Array] = {}
        host: Dict[str, jax.Array] = {}
        for t, info in graph.tensors.items():
            if t in inputs:
                if info.initial_location == "remote":
                    host[t] = to_host(inputs[t])
                else:
                    env[t] = jax.device_put(inputs[t], self.dev_sharding)

        produced = set(env) | set(host)
        for name in order:
            node = graph.nodes[name]
            if node.kind == "compute":
                args = [env[t] for t in node.inputs]
                outs = self.fns[name](*args)
                if not isinstance(outs, (tuple, list)):
                    outs = (outs,)
                if len(outs) != len(node.outputs):
                    raise ValueError(
                        f"{name}: fn returned {len(outs)} values, node declares "
                        f"{len(node.outputs)} outputs")
                for t, v in zip(node.outputs, outs):
                    env[t] = v
                    produced.add(t)
            elif node.kind == "prefetch":
                env[node.tensor] = jax.device_put(host[node.tensor], self.dev_sharding)
            elif node.kind == "store":
                host[node.tensor] = to_host(env[node.tensor])
            elif node.kind == "detach":
                env.pop(node.tensor, None)

        result = dict(env)
        for t, v in host.items():
            result.setdefault(t, v)
        return result


def run_baseline(graph: Graph, compute_fns: Mapping[str, Callable],
                 inputs: Mapping[str, jax.Array]) -> Dict[str, jax.Array]:
    """Everything-resident reference execution (no cache ops)."""
    base = graph.residentize()
    return PlanExecutor(base, compute_fns).run(inputs)
