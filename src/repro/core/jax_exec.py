"""Synchronous plan execution on real JAX arrays — thin wrapper over
``pool.executor.OffloadPlanExecutor``.

The seed carried two node-walk dispatch loops over the same IR semantics:
this module's original executor (sync ``device_put`` per cache op) and the
pool executor (async transfers + residency ledger). They are now folded:
``PlanExecutor`` keeps the seed-era API — all compute fns must be bound,
``run`` returns one flat environment in which host-parked tensors reappear
under their names — but every cache operator is driven by the
``MemoryPoolManager``'s tiered backends and transfer engine. A plan that
validates in the compiler still runs, and produces values identical to the
everything-resident baseline (tests/test_substrates.py).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Optional, Sequence

import jax

from repro.core.ir import Graph
from repro.pool.executor import OffloadPlanExecutor
from repro.pool.manager import MemoryPoolManager, default_pool


class PlanExecutor:
    """Sync facade: validates fn bindings eagerly, owns a throwaway pool
    per ``run`` unless one is injected, and waits every transfer before
    returning.

    The front-door spelling is ``session.executor(graph, fns)``
    (`repro.api.HyperOffloadSession`), which injects the session's shared
    pool; ``session=`` here accepts any object with a ``.pool`` and is
    equivalent."""

    def __init__(self, graph: Graph,
                 compute_fns: Mapping[str, Callable],
                 device: Optional[jax.Device] = None,
                 pool: Optional[MemoryPoolManager] = None,
                 session: Optional[Any] = None) -> None:
        self.graph = graph
        self.fns = dict(compute_fns)
        self.device = device or jax.devices()[0]
        if pool is None and session is not None:
            pool = session.pool
        self._pool = pool
        missing = [n for n, node in graph.nodes.items()
                   if node.kind == "compute" and n not in self.fns]
        if missing:
            raise ValueError(f"no compute fn bound for {missing}")

    def run(self, inputs: Mapping[str, jax.Array],
            order: Optional[Sequence[str]] = None) -> Dict[str, jax.Array]:
        """``inputs`` must provide every tensor with no producer (weights,
        states, graph inputs). Returns the final environment: device-resident
        tensors plus pool-parked tensors under their names."""
        own_pool = self._pool is None
        pool = self._pool if self._pool is not None else default_pool(
            device=self.device)
        ex = OffloadPlanExecutor(self.graph, pool, self.fns)
        try:
            env, _ = ex.run(inputs, order)
            result = dict(env)
            for t in self.graph.tensors:
                if t not in result and ex._key(t) in pool:
                    result[t] = pool.get(ex._key(t))
            return result
        finally:
            # sync contract: nothing outlives the call — parked entries are
            # surfaced in the result above, so drop them from the pool (an
            # injected pool would otherwise accumulate one exec<N>/ copy of
            # every offloaded tensor per run)
            for t in self.graph.tensors:
                if ex._key(t) in pool:
                    pool.drop(ex._key(t))
            if own_pool:
                pool.close()


def run_baseline(graph: Graph, compute_fns: Mapping[str, Callable],
                 inputs: Mapping[str, jax.Array]) -> Dict[str, jax.Array]:
    """Everything-resident reference execution (no cache ops)."""
    base = graph.residentize()
    return PlanExecutor(base, compute_fns).run(inputs)
