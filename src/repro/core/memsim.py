"""Device-memory ledger: peak usage of an execution order.

Walks the order maintaining the set of device-resident tensors under the IR
memory semantics (ir.py docstring). This is the compiler's deterministic
memory plan — the quantity HyperOffload minimizes subject to not stalling
compute (§3.3's residency/overlap trade-off).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.ir import Graph


@dataclass
class MemoryTrace:
    peak_bytes: int
    peak_pos: int
    usage: List[int]                      # resident bytes after each node
    resident_at_peak: Tuple[str, ...] = ()
    # event trace for the allocator simulator: (pos, "alloc"/"free", tensor)
    events: List[Tuple[int, str, str]] = field(default_factory=list)


def simulate(graph: Graph, order: Optional[Sequence[str]] = None) -> MemoryTrace:
    order = list(order) if order is not None else graph.order()
    graph.validate_order(order)
    pos = {n: i for i, n in enumerate(order)}

    # last read of each tensor (by compute or store) under this order
    last_read: Dict[str, int] = {}
    for name in order:
        node = graph.nodes[name]
        for t in node.reads():
            last_read[t] = pos[name]

    produced = {t for n in graph.nodes.values() for t in n.writes()
                if n.kind == "compute"}
    resident: Dict[str, int] = {}
    events: List[Tuple[int, str, str]] = []
    for t, info in graph.tensors.items():
        # initially resident: device-located graph INPUTS (weights/states);
        # tensors produced by compute nodes materialize at their producer
        if info.initial_location == "device" and t not in produced:
            resident[t] = info.nbytes
            events.append((-1, "alloc", t))

    usage: List[int] = []
    cur = sum(resident.values())
    peak, peak_pos, peak_set = cur, -1, tuple(resident)

    def free(t: str, p: int) -> None:
        nonlocal cur
        if t in resident:
            cur -= resident.pop(t)
            events.append((p, "free", t))

    def alloc(t: str, p: int) -> None:
        nonlocal cur
        if t not in resident:
            resident[t] = graph.tensors[t].nbytes
            cur += resident[t]
            events.append((p, "alloc", t))

    for i, name in enumerate(order):
        node = graph.nodes[name]
        if node.kind == "compute":
            for t in node.outputs:
                alloc(t, i)
        elif node.kind == "prefetch":
            alloc(node.tensor, i)
        elif node.kind == "detach":
            free(node.tensor, i)
        # release dead ordinary tensors (activations past their last read)
        for t in node.reads():
            info = graph.tensors[t]
            if info.klass == "activation" and last_read.get(t, -1) == i:
                free(t, i)
        if cur > peak:
            peak, peak_pos, peak_set = cur, i, tuple(resident)
        usage.append(cur)

    return MemoryTrace(peak_bytes=peak, peak_pos=peak_pos, usage=usage,
                       resident_at_peak=peak_set, events=events)


def peak_bytes(graph: Graph, order: Optional[Sequence[str]] = None) -> int:
    return simulate(graph, order).peak_bytes
