"""Dual-stream execution-timeline simulator.

Models the device as one compute stream plus two DMA channels (d2r / r2d,
duplex pool link). Nodes are *issued* in program order; each starts at
max(its stream's free time, completion of its dependencies) — i.e. transfers
issued early run asynchronously under compute, which is exactly the overlap
the paper's Figure 3(c) idealizes.

Also provides the *reactive runtime* baseline of §3.1: no cache operators —
instead a capacity-limited device where memory pressure triggers synchronous
LRU eviction and reads of evicted tensors stall compute for a synchronous
reload, each paying a CPU runtime-intervention cost.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import HardwareSpec
from repro.core.ir import Graph


@dataclass
class Timeline:
    total: float
    compute_busy: float
    exposed_comm: float              # compute-stream idle time
    dma_busy_d2r: float
    dma_busy_r2d: float
    schedule: Dict[str, Tuple[float, float, str]]  # name -> (start, end, stream)
    stalls: int = 0                  # reactive baseline: synchronous events
    defrag_time: float = 0.0


def _node_stream(kind: str) -> str:
    if kind == "store":
        return "d2r"
    if kind == "prefetch":
        return "r2d"
    if kind == "detach":
        return "meta"   # zero-cost bookkeeping: must not stall compute
    return "compute"


def _duration(node, hw: HardwareSpec, graph: Graph) -> float:
    if node.kind == "compute":
        return hw.compute_time(node.flops, node.hbm_bytes)
    if node.kind == "store":
        return hw.transfer_time(graph.tensors[node.tensor].nbytes, "d2r")
    if node.kind == "prefetch":
        return hw.transfer_time(graph.tensors[node.tensor].nbytes, "r2d")
    return 0.0  # detach


def simulate(graph: Graph, hw: HardwareSpec,
             order: Optional[Sequence[str]] = None) -> Timeline:
    order = list(order) if order is not None else graph.order()
    deps = graph.dependencies(order)
    free = {"compute": 0.0, "d2r": 0.0, "r2d": 0.0, "meta": 0.0}
    end: Dict[str, float] = {}
    sched: Dict[str, Tuple[float, float, str]] = {}
    busy = {"compute": 0.0, "d2r": 0.0, "r2d": 0.0, "meta": 0.0}

    for name in order:
        node = graph.nodes[name]
        stream = _node_stream(node.kind)
        ready = max((end.get(d, 0.0) for d in deps[name]), default=0.0)
        start = max(ready, free[stream])
        dur = _duration(node, hw, graph)
        t_end = start + dur
        free[stream] = t_end
        busy[stream] += dur
        end[name] = t_end
        sched[name] = (start, t_end, stream)

    total = max(end.values(), default=0.0)
    return Timeline(
        total=total,
        compute_busy=busy["compute"],
        exposed_comm=max(0.0, total - busy["compute"]),
        dma_busy_d2r=busy["d2r"],
        dma_busy_r2d=busy["r2d"],
        schedule=sched,
    )


# ---------------------------------------------------------------------------
# Reactive runtime baseline (§3.1)
# ---------------------------------------------------------------------------


def simulate_reactive(graph: Graph, hw: HardwareSpec,
                      capacity: float,
                      order: Optional[Sequence[str]] = None) -> Timeline:
    """Runtime-driven swapping: evict LRU on pressure, reload on demand.
    All transfers are synchronous on the compute stream (the runtime cannot
    see the future, so nothing is prefetched) and each event pays
    ``hw.runtime_intervention``. Cache ops in the graph are ignored."""
    order = [n for n in (order or graph.order())
             if graph.nodes[n].kind == "compute"]
    pos = {n: i for i, n in enumerate(order)}
    last_read: Dict[str, int] = {}
    for name in order:
        for t in graph.nodes[name].inputs:
            last_read[t] = pos[name]

    resident: Dict[str, int] = {}
    lru: Dict[str, int] = {}
    evicted: set = set()
    t_now = 0.0
    compute_busy = 0.0
    stalls = 0

    def nbytes(t: str) -> int:
        return graph.tensors[t].nbytes

    produced = {t for n in graph.nodes.values() for t in n.writes()
                if n.kind == "compute"}
    for t, info in graph.tensors.items():
        if info.initial_location == "device" and t not in produced:
            resident[t] = nbytes(t)
            lru[t] = -1

    def make_room(needed: int, step: int) -> None:
        nonlocal t_now, stalls
        while sum(resident.values()) + needed > capacity and resident:
            victim = min(lru, key=lru.get)
            t_now += hw.runtime_intervention + hw.transfer_time(resident[victim], "d2r")
            stalls += 1
            evicted.add(victim)
            resident.pop(victim)
            lru.pop(victim)

    for i, name in enumerate(order):
        node = graph.nodes[name]
        # demand-load evicted inputs (synchronous: exposed latency)
        for t in node.inputs:
            if t not in resident:
                make_room(nbytes(t), i)
                t_now += hw.runtime_intervention + hw.transfer_time(nbytes(t), "r2d")
                stalls += 1
                resident[t] = nbytes(t)
            lru[t] = i
        out_bytes = sum(nbytes(t) for t in node.outputs if t not in resident)
        make_room(out_bytes, i)
        for t in node.outputs:
            resident.setdefault(t, nbytes(t))
            lru[t] = i
        dur = hw.compute_time(node.flops, node.hbm_bytes)
        t_now += dur
        compute_busy += dur
        # free dead activations
        for t in list(resident):
            info = graph.tensors[t]
            if info.klass == "activation" and last_read.get(t, -1) <= i and t not in node.outputs:
                if last_read.get(t, -1) == i:
                    resident.pop(t)
                    lru.pop(t, None)

    return Timeline(
        total=t_now,
        compute_busy=compute_busy,
        exposed_comm=max(0.0, t_now - compute_busy),
        dma_busy_d2r=0.0,
        dma_busy_r2d=0.0,
        schedule={},
        stalls=stalls,
    )
