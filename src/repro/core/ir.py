"""Layer-level computation-graph IR with first-class cache operators.

This is the analogue of the paper's MindIR extension (§4.2): compute nodes
carry analytic FLOP/byte costs; ``prefetch`` / ``store`` / ``detach`` nodes
represent remote-pool traffic and participate in dependency analysis and
topological ordering exactly like compute. Memory semantics (used by
``memsim`` and ``timeline``):

- a tensor is *device-resident* from its producing node (compute or
  prefetch) until freed — after its last consumer for ordinary tensors,
  or by an explicit ``detach`` for persistent ones (weights, states);
- ``store t`` copies t device→remote (t must be device-resident);
- ``detach t`` drops the device copy (legal only if a remote copy exists
  or t has no later consumer);
- ``prefetch t`` copies remote→device (a remote copy must exist; weights
  and states may start remote-resident).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

CACHE_KINDS = ("prefetch", "store", "detach")


@dataclass(frozen=True)
class TensorInfo:
    name: str
    nbytes: int
    # "activation" — produced on device during the step
    # "weight"     — persistent input, device-resident by default
    # "state"      — persistent (optimizer/KV), may start remote
    klass: str = "activation"
    initial_location: str = "device"   # device | remote


@dataclass
class Node:
    name: str
    kind: str                      # "compute" | "prefetch" | "store" | "detach"
    inputs: Tuple[str, ...] = ()   # tensors read (compute only)
    outputs: Tuple[str, ...] = ()  # tensors produced (compute only)
    flops: float = 0.0
    hbm_bytes: float = 0.0         # bytes touched in HBM (compute roofline)
    tensor: Optional[str] = None   # cache ops: the tensor moved
    after: Tuple[str, ...] = ()    # extra explicit control deps (node names)

    @property
    def is_cache_op(self) -> bool:
        return self.kind in CACHE_KINDS

    def reads(self) -> Tuple[str, ...]:
        if self.kind == "compute":
            return self.inputs
        if self.kind in ("store",):
            return (self.tensor,)
        return ()

    def writes(self) -> Tuple[str, ...]:
        if self.kind == "compute":
            return self.outputs
        if self.kind == "prefetch":
            return (self.tensor,)
        return ()


class Graph:
    """A DAG of nodes over named tensors. Node insertion order is preserved
    and serves as the default (valid) topological order."""

    def __init__(self) -> None:
        self.nodes: Dict[str, Node] = {}
        self.tensors: Dict[str, TensorInfo] = {}

    # -- construction -------------------------------------------------------
    def add_tensor(self, name: str, nbytes: int, klass: str = "activation",
                   initial_location: str = "device") -> TensorInfo:
        if name in self.tensors:
            raise ValueError(f"duplicate tensor {name!r}")
        t = TensorInfo(name, int(nbytes), klass, initial_location)
        self.tensors[name] = t
        return t

    def add_node(self, node: Node) -> Node:
        if node.name in self.nodes:
            raise ValueError(f"duplicate node {node.name!r}")
        for t in (*node.reads(), *node.writes()):
            if t not in self.tensors:
                raise ValueError(f"node {node.name!r} references unknown tensor {t!r}")
        self.nodes[node.name] = node
        return node

    def compute(self, name: str, inputs: Sequence[str] = (),
                outputs: Sequence[str] = (), flops: float = 0.0,
                hbm_bytes: float = 0.0, after: Sequence[str] = ()) -> Node:
        return self.add_node(Node(name, "compute", tuple(inputs), tuple(outputs),
                                  flops, hbm_bytes, after=tuple(after)))

    def prefetch(self, tensor: str, name: Optional[str] = None,
                 after: Sequence[str] = ()) -> Node:
        return self.add_node(Node(name or f"prefetch::{tensor}", "prefetch",
                                  tensor=tensor, after=tuple(after)))

    def store(self, tensor: str, name: Optional[str] = None,
              after: Sequence[str] = ()) -> Node:
        return self.add_node(Node(name or f"store::{tensor}", "store",
                                  tensor=tensor, after=tuple(after)))

    def detach(self, tensor: str, name: Optional[str] = None,
               after: Sequence[str] = ()) -> Node:
        return self.add_node(Node(name or f"detach::{tensor}", "detach",
                                  tensor=tensor, after=tuple(after)))

    # -- queries --------------------------------------------------------------
    def order(self) -> List[str]:
        return list(self.nodes)

    def producers(self) -> Dict[str, str]:
        """tensor -> producing compute/prefetch node (first writer)."""
        out: Dict[str, str] = {}
        for n in self.nodes.values():
            for t in n.writes():
                out.setdefault(t, n.name)
        return out

    def consumers(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {t: [] for t in self.tensors}
        for n in self.nodes.values():
            for t in n.reads():
                out[t].append(n.name)
        return out

    def dependencies(self, order: Optional[Sequence[str]] = None) -> Dict[str, List[str]]:
        """node -> list of node names it depends on (data + cache-legality
        + explicit control deps). Cache-op data deps:

        - prefetch t: after the most recent ``store t`` (or none if t starts
          remote / is persistent with a standing remote copy);
        - store t: after t's producer (t must exist on device);
        - detach t: after the store of t (remote copy) and after every
          consumer of t that precedes the next prefetch — we conservatively
          require all reads of t *before this detach in program order*.
        """
        order = list(order) if order is not None else self.order()
        pos = {n: i for i, n in enumerate(order)}
        deps: Dict[str, List[str]] = {n: [] for n in order}

        produced_by: Dict[str, str] = {}
        last_store: Dict[str, str] = {}
        readers_so_far: Dict[str, List[str]] = {t: [] for t in self.tensors}

        for name in order:
            node = self.nodes[name]
            d: List[str] = list(node.after)
            if node.kind == "compute":
                for t in node.inputs:
                    # depend on the latest producing event of t before us
                    p = self._latest_writer(t, pos[name], order)
                    if p is not None:
                        d.append(p)
            elif node.kind == "store":
                p = self._latest_writer(node.tensor, pos[name], order)
                if p is not None:
                    d.append(p)
            elif node.kind == "prefetch":
                s = self._latest_event(node.tensor, pos[name], order, ("store",))
                if s is not None:
                    d.append(s)
            elif node.kind == "detach":
                t = node.tensor
                s = self._latest_event(t, pos[name], order, ("store",))
                if s is not None:
                    d.append(s)
                d.extend(readers_so_far[t])
            for t in node.reads():
                readers_so_far[t].append(name)
            deps[name] = sorted(set(d), key=lambda n: pos.get(n, -1))
        return deps

    def _latest_writer(self, tensor: str, before: int, order: Sequence[str]) -> Optional[str]:
        return self._latest_event(tensor, before, order, ("compute", "prefetch"))

    def _latest_event(self, tensor: str, before: int, order: Sequence[str],
                      kinds: Tuple[str, ...]) -> Optional[str]:
        for i in range(before - 1, -1, -1):
            n = self.nodes[order[i]]
            if n.kind not in kinds:
                continue
            if n.kind == "compute":
                if tensor in n.outputs:
                    return n.name
            elif n.tensor == tensor:
                return n.name
        return None

    # -- validation -----------------------------------------------------------
    def validate_order(self, order: Sequence[str]) -> None:
        """Raise if ``order`` is not a valid execution of this graph."""
        order = list(order)
        if sorted(order) != sorted(self.nodes):
            raise ValueError("order must be a permutation of all nodes")
        produced = {t for n in self.nodes.values() for t in n.writes()
                    if n.kind == "compute"}
        resident = {t: (info.initial_location == "device" and t not in produced)
                    for t, info in self.tensors.items()}
        remote = {t: (info.initial_location == "remote")
                  for t, info in self.tensors.items()}
        pos = {n: i for i, n in enumerate(order)}
        for name in order:
            node = self.nodes[name]
            for dep in node.after:
                if pos[dep] >= pos[name]:
                    raise ValueError(f"{name} before its control dep {dep}")
            if node.kind == "compute":
                for t in node.inputs:
                    if not resident[t]:
                        raise ValueError(f"{name} reads non-resident tensor {t}")
                for t in node.outputs:
                    resident[t] = True
            elif node.kind == "store":
                if not resident[node.tensor]:
                    raise ValueError(f"{name}: store of non-resident {node.tensor}")
                remote[node.tensor] = True
            elif node.kind == "prefetch":
                if not remote[node.tensor]:
                    raise ValueError(f"{name}: prefetch of {node.tensor} with no remote copy")
                resident[node.tensor] = True
            elif node.kind == "detach":
                if not resident[node.tensor]:
                    raise ValueError(f"{name}: detach of non-resident {node.tensor}")
                # future reads must be preceded by a prefetch — checked by the
                # compute-read rule as we continue the walk
                resident[node.tensor] = False

    def copy(self) -> "Graph":
        g = Graph()
        g.tensors = dict(self.tensors)
        g.nodes = {k: dataclasses.replace(v) for k, v in self.nodes.items()}
        return g

    def residentize(self) -> "Graph":
        """Everything-on-device baseline: all tensors start device-resident
        and cache operators are stripped (the paper's no-offload baseline)."""
        g = Graph()
        g.tensors = {
            t: dataclasses.replace(info, initial_location="device")
            for t, info in self.tensors.items()
        }
        g.nodes = {k: dataclasses.replace(v) for k, v in self.nodes.items()
                   if not v.is_cache_op}
        return g
