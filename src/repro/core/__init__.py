"""HyperOffload core: graph-driven hierarchical memory management.

The paper's contribution, reimplemented as a compiler layer over a
layer-level IR:

- ``ir``         — computation graph with first-class cache operators
- ``costmodel``  — SuperNode/TPU hardware model (compute, HBM, pool links)
- ``lifetime``   — tensor lifetime analysis over an execution order
- ``memsim``     — device-memory ledger: peak usage for a given order
- ``allocator``  — fragmentation-aware allocator simulator (defrag events)
- ``insertion``  — compile-time Prefetch/Store/Detach insertion (§4.2.2)
- ``schedule``   — Algorithm 1: graph-driven execution-order optimization
- ``timeline``   — dual-stream (compute + DMA) execution timeline simulator
- ``planner``    — end-to-end pipeline producing an OffloadPlan
- ``calibration``— measured transfer telemetry → CalibratedHardwareSpec
- ``tracer``     — ModelConfig → layer-level graphs (train/prefill/decode)
- ``jax_exec``   — execute a plan on real JAX arrays with a host-side pool
"""

from repro.core.calibration import (
    CalibratedHardwareSpec, TierPairMeasurement, calibrate,
    measurements_from_pairs, required_inflight,
)
from repro.core.costmodel import HardwareSpec, ASCEND_LIKE, TPU_V5E
from repro.core.ir import Graph, Node, TensorInfo
from repro.core.planner import HyperOffloadPlanner, OffloadPlan

__all__ = [
    "Graph",
    "Node",
    "TensorInfo",
    "HardwareSpec",
    "ASCEND_LIKE",
    "TPU_V5E",
    "CalibratedHardwareSpec",
    "TierPairMeasurement",
    "calibrate",
    "measurements_from_pairs",
    "required_inflight",
    "HyperOffloadPlanner",
    "OffloadPlan",
]
