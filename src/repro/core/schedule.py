"""Graph-Driven Execution-Order Optimization — Algorithm 1 of the paper.

Starting from a valid topological order, each *independent* cache operator
(prefetches, whose only constraints are "after the matching store / remote
copy" and "before the first consumer") is tried at a set of feasible
positions. A cost model scores each position on (a) exposed communication
latency — does the transfer complete before the consumer needs it? — and
(b) memory residency — how long does the prefetched tensor sit idle in
device memory? The placement minimizing the combined cost is kept.

This resolves the §3.3 trade-off: too late ⇒ stalls (Fig. 4a); too early ⇒
residency waste (Fig. 4b); Algorithm 1 lands just-in-time (Fig. 4c).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core import memsim, timeline
from repro.core.costmodel import HardwareSpec
from repro.core.ir import Graph


@dataclass(frozen=True)
class ScheduleOptions:
    max_candidates: int = 24          # feasible positions sampled per cache op
    mem_weight: float = 1.0           # λ: seconds of cost per (HBM of residency)·s
    passes: int = 1


def _first_consumer_pos(graph: Graph, order: List[str], tensor: str,
                        after: int) -> Optional[int]:
    for i in range(after + 1, len(order)):
        node = graph.nodes[order[i]]
        if node.kind == "compute" and tensor in node.inputs:
            return i
    return None


def _earliest_legal_pos(graph: Graph, order: List[str], c_idx: int) -> int:
    """A prefetch may move up to just after its matching store (or to the
    front if the tensor starts remote) and after its explicit control deps."""
    node = graph.nodes[order[c_idx]]
    lo = 0
    for i in range(c_idx - 1, -1, -1):
        n = graph.nodes[order[i]]
        if n.kind in ("store", "detach") and n.tensor == node.tensor:
            lo = i + 1
            break
    pos = {name: i for i, name in enumerate(order)}
    for dep in node.after:
        lo = max(lo, pos[dep] + 1)
    return lo


def _cost(graph: Graph, order: List[str], hw: HardwareSpec, c_name: str,
          u_pos: Optional[int], opts: ScheduleOptions) -> float:
    tl = timeline.simulate(graph, hw, order)
    # latency term: exposed communication on the compute stream.
    # memory term: peak residency of this order (the device buffer is
    # reserved at DMA issue — the position-based ledger captures early-issue
    # waste that wall-clock DMA start times alone would hide).
    mem = memsim.simulate(graph, order)
    return (tl.exposed_comm
            + opts.mem_weight * (mem.peak_bytes / hw.hbm_bytes) * max(tl.total, 1e-9))


def refine_order(graph: Graph, hw: HardwareSpec,
                 order: Optional[Sequence[str]] = None,
                 opts: ScheduleOptions = ScheduleOptions()) -> List[str]:
    """Algorithm 1. Returns a refined order (a permutation of all nodes that
    still validates). The input graph is not modified."""
    order = list(order) if order is not None else graph.order()
    graph.validate_order(order)

    for _ in range(opts.passes):
        cache_ops = [n for n in order if graph.nodes[n].kind == "prefetch"]
        for c_name in cache_ops:
            c_idx = order.index(c_name)
            tensor = graph.nodes[c_name].tensor
            lo = _earliest_legal_pos(graph, order, c_idx)
            # first consumer *after* the earliest legal point (uses before the
            # offload gap — e.g. the forward pass — don't bound this prefetch)
            u_pos = _first_consumer_pos(graph, order, tensor, lo - 1)
            hi = u_pos if u_pos is not None else len(order)
            if hi <= lo:
                continue
            # candidate insertion positions in [lo, hi)
            span = hi - lo
            if span <= opts.max_candidates:
                cand = list(range(lo, hi))
            else:
                step = span / opts.max_candidates
                cand = sorted({lo + int(i * step) for i in range(opts.max_candidates)} | {hi - 1})
            cand.reverse()  # evaluate latest-first: ties resolve to minimal residency
            best_order, best_cost = None, None
            for p in cand:
                trial = order.copy()
                trial.remove(c_name)
                # removing shifts indices after c_idx left by one
                insert_at = p if p <= c_idx else p - 1
                trial.insert(insert_at, c_name)
                try:
                    graph.validate_order(trial)
                except ValueError:
                    continue
                u_now = _first_consumer_pos(graph, trial, tensor, insert_at)
                cost = _cost(graph, trial, hw, c_name, u_now, opts)
                if best_cost is None or cost < best_cost - 1e-12:
                    best_cost, best_order = cost, trial
            if best_order is not None:
                order = best_order
    graph.validate_order(order)
    return order
