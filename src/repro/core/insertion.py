"""Compile-time cache-operator insertion (§4.2.2).

Given a plain compute graph, decide which tensors are worth parking in the
remote pool and materialize the decision as Store/Detach/Prefetch nodes:

- *activations* with a long idle gap (produced in forward, consumed in
  backward): offload if the gap's estimated compute time covers the
  round-trip transfer and the tensor is large enough to matter. Short-lived
  or fine-grained tensors are rejected by the same test — the paper's §5.1
  "not good candidates" rule falls out of the cost model.
- *weights/states* declared remote-initial (optimizer states, offloaded KV
  blocks, cold expert weights): a Prefetch lands before the first consumer;
  if a consumer *writes* a successor state tensor, the successor gets
  Store+Detach after its producer.

The ops are inserted at conservative (late-prefetch) positions; Algorithm 1
(schedule.refine_order) then slides them to just-in-time positions.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import lifetime as lt
from repro.core.costmodel import HardwareSpec
from repro.core.ir import Graph, Node


@dataclass(frozen=True)
class InsertionOptions:
    min_bytes: int = 1 << 20          # ignore tensors below 1 MiB
    safety: float = 1.25              # required idle-time / transfer-time ratio
    offload_activations: bool = True
    offload_states: bool = True
    # aggregate DMA budget: total offload traffic per direction may use at
    # most this fraction of the step's compute time — offloading more than
    # the link can hide only converts memory pressure into exposed latency
    bandwidth_budget: float = 0.9
    # tensors whose name starts with one of these prefixes (or appears in
    # force_tensors) are offloaded unconditionally (capacity-driven, e.g. KV
    # caches in the paper's Table 3 — the decode slowdown is accepted for
    # the memory win)
    force_prefixes: Tuple[str, ...] = ()
    force_tensors: Tuple[str, ...] = ()


#: The paged-serving default (``OffloadConfig`` modes ``paged`` /
#: ``kv_offload`` / ``continuous``): pool-resident KV tensors *must* be
#: planned — their prefetch is mandatory, not a cost-model choice — so the
#: size filter is disabled. Was hard-coded at the PlanPrefetcher call site
#: before the ``repro.api`` front door existed.
PAGED_INSERTION = InsertionOptions(min_bytes=1)


def _node_durations(graph: Graph, hw: HardwareSpec,
                    order: Sequence[str]) -> Dict[str, float]:
    return {
        n: hw.compute_time(graph.nodes[n].flops, graph.nodes[n].hbm_bytes)
        if graph.nodes[n].kind == "compute" else 0.0
        for n in order
    }


def _rebuild(graph: Graph, order: Sequence[str]) -> Graph:
    g = Graph()
    g.tensors = dict(graph.tensors)
    for name in order:
        g.nodes[name] = graph.nodes[name]
    return g


def insert_cache_ops(graph: Graph, hw: HardwareSpec,
                     opts: InsertionOptions = InsertionOptions()) -> Graph:
    """Returns a new Graph containing cache operators. Node objects are
    shared; ordering is the original order with cache ops spliced in."""
    order = graph.order()
    lifetimes = lt.analyze(graph, order)
    durations = _node_durations(graph, hw, order)
    # prefix[i] = total compute time of nodes [0, i)
    prefix: List[float] = [0.0]
    for n in order:
        prefix.append(prefix[-1] + durations[n])

    def window_time(a: int, b: int) -> float:
        """Compute time strictly between positions a and b."""
        return prefix[b] - prefix[a + 1]

    inserts: List[Tuple[int, Node]] = []   # (position before which to insert, node)
    # opportunistic candidates competing for the DMA budget:
    # (priority, d2r_cost, r2d_cost, [(pos, Node), ...])
    candidates: List[Tuple[float, float, float, List[Tuple[int, Node]]]] = []

    force_set = frozenset(opts.force_tensors)

    def forced(t: str) -> bool:
        return t in force_set or any(t.startswith(p) for p in opts.force_prefixes)

    for t, life in lifetimes.items():
        info = graph.tensors[t]
        if info.nbytes < opts.min_bytes:
            continue
        d2r = hw.transfer_time(info.nbytes, "d2r")
        r2d = hw.transfer_time(info.nbytes, "r2d")

        if info.klass == "activation" and (opts.offload_activations or forced(t)):
            if life.producer_pos is None or not life.use_positions:
                continue
            g0, g1 = life.longest_gap()
            if g1 - g0 <= 1:
                continue
            idle = window_time(g0, g1)
            if idle < (d2r + r2d) * opts.safety and not forced(t):
                continue  # transfer can't amortize — keep resident (§5.1)
            ops = [(g0 + 1, Node(f"store::{t}", "store", tensor=t)),
                   (g0 + 1, Node(f"detach::{t}", "detach", tensor=t)),
                   (g1, Node(f"prefetch::{t}", "prefetch", tensor=t))]
            if forced(t):
                inserts.extend(ops)
            else:
                # priority: memory-seconds saved per second of link time
                saved = info.nbytes * idle
                candidates.append((saved / max(d2r + r2d, 1e-12), d2r, r2d, ops))

        elif info.klass in ("weight", "state") and (opts.offload_states or forced(t)):
            if info.initial_location == "remote":
                # the tensor LIVES in the pool — its prefetch is mandatory
                # (correctness), never subject to the bandwidth budget
                if not life.use_positions:
                    continue
                first = life.first_use
                inserts.append((first, Node(f"prefetch::{t}", "prefetch", tensor=t)))
                # park it again after its last use if the tail can absorb it
                last = life.last_use
                tail = prefix[-1] - prefix[last + 1]
                if tail >= d2r:
                    inserts.append((last + 1, Node(f"detach::{t}", "detach", tensor=t)))
            elif (info.klass == "state" and life.producer_pos is not None
                  and (life.last_use is None or life.last_use < life.producer_pos)):
                # state produced in-step and not read again (e.g. updated
                # optimizer moments, freshly appended KV blocks): stream it
                # back to the pool right after its producer
                p = life.producer_pos
                ops = [(p + 1, Node(f"store::{t}", "store", tensor=t)),
                       (p + 1, Node(f"detach::{t}", "detach", tensor=t))]
                if forced(t):
                    inserts.extend(ops)
                else:
                    tail = prefix[-1] - prefix[p + 1]
                    candidates.append((info.nbytes * max(tail, 1e-9) / max(d2r, 1e-12),
                                       d2r, 0.0, ops))

    # greedy selection under the per-direction DMA budget
    budget = opts.bandwidth_budget * prefix[-1]
    used_d2r = used_r2d = 0.0
    for prio, c_d2r, c_r2d, ops in sorted(candidates, key=lambda c: -c[0]):
        if used_d2r + c_d2r > budget or used_r2d + c_r2d > budget:
            continue
        used_d2r += c_d2r
        used_r2d += c_r2d
        inserts.extend(ops)

    # splice: stable sort by target position; store before detach before
    # prefetch at equal positions (store must precede its detach)
    kind_rank = {"store": 0, "detach": 1, "prefetch": 2}
    inserts.sort(key=lambda x: (x[0], kind_rank[x[1].kind]))
    new_order: List[str] = []
    nodes: Dict[str, Node] = {}
    it = iter(inserts)
    pending = next(it, None)
    for i, name in enumerate(order):
        while pending is not None and pending[0] <= i:
            nodes[pending[1].name] = pending[1]
            new_order.append(pending[1].name)
            pending = next(it, None)
        nodes[name] = graph.nodes[name]
        new_order.append(name)
    while pending is not None:
        nodes[pending[1].name] = pending[1]
        new_order.append(pending[1].name)
        pending = next(it, None)

    # remote-initial tensors whose prefetch was NOT selected (over budget)
    # simply stay device-resident — flip their initial location
    prefetched = {n.tensor for _, n in inserts if n.kind == "prefetch"}
    tensors = {}
    for t, info in graph.tensors.items():
        if info.initial_location == "remote" and t not in prefetched:
            import dataclasses as _dc
            info = _dc.replace(info, initial_location="device")
        tensors[t] = info

    g = Graph()
    g.tensors = tensors
    g.nodes = {n: nodes[n] for n in new_order}
    g.validate_order(g.order())
    return g
