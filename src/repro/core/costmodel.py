"""Hardware model for the SuperNode memory hierarchy.

The paper's platform is an Ascend 910C node attached to a shared memory pool
(CloudMatrix384 Unified Bus); ours is TPU v5e with host/pooled DRAM as the
remote tier. Both reduce to the same four numbers per device: peak FLOP/s,
HBM bandwidth, remote-pool bandwidth (per direction), and HBM capacity.
The pool bandwidth is deliberately sweepable — Figure 6 of the paper sweeps
D2H bandwidth 33.6→70 GB/s and we reproduce that experiment directly.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    flops: float              # peak FLOP/s per device (bf16)
    hbm_bw: float             # HBM bytes/s
    hbm_bytes: float          # device memory capacity
    pool_bw_d2r: float        # device -> remote pool bytes/s
    pool_bw_r2d: float        # remote pool -> device bytes/s
    link_bw: float            # inter-chip interconnect bytes/s per link
    dma_issue_overhead: float = 2e-6   # fixed cost to launch one DMA
    runtime_intervention: float = 30e-6  # CPU runtime swap decision cost
                                         # (reactive baseline only, §3.1)

    def with_pool_bw(self, bw: float) -> "HardwareSpec":
        return replace(self, pool_bw_d2r=bw, pool_bw_r2d=bw)

    # ------------------------------------------------------------------
    def compute_time(self, flops: float, hbm_bytes: float) -> float:
        """Roofline node time: max of compute and memory terms."""
        return max(flops / self.flops, hbm_bytes / self.hbm_bw)

    def transfer_time(self, nbytes: float, direction: str) -> float:
        bw = self.pool_bw_d2r if direction == "d2r" else self.pool_bw_r2d
        return self.dma_issue_overhead + nbytes / bw


# TPU v5e (per chip) — target hardware for the framework.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    pool_bw_d2r=50e9,
    pool_bw_r2d=50e9,
    link_bw=50e9,
)

# Ascend-910C-like single device used to reproduce the paper's own numbers.
# The paper's measured D2H bandwidth is 33.6 GB/s (§7.2.1); HBM ~1.6 TB/s
# and ~280 TFLOP/s bf16 per 910C die pair are public figures (the exact
# values only shift absolute times — the reproduced quantities are ratios).
ASCEND_LIKE = HardwareSpec(
    name="ascend_910c_like",
    flops=280e12,
    hbm_bw=1.6e12,
    hbm_bytes=64e9,
    pool_bw_d2r=33.6e9,
    pool_bw_r2d=33.6e9,
    link_bw=56e9,
)
