"""Tensor lifetime analysis over an execution order (§4.2.2).

For each tensor: producer position, consumer positions, the *lifetime gap*
structure (intervals between consecutive uses where the tensor is resident
but idle), and the free position (where a non-persistent tensor dies).
The insertion pass uses gaps to pick offload candidates: a tensor is worth
parking in the remote pool iff some idle interval is long enough to amortize
a round-trip transfer.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.ir import Graph


@dataclass(frozen=True)
class Lifetime:
    tensor: str
    nbytes: int
    klass: str
    producer_pos: Optional[int]      # None for graph inputs (weights/states)
    use_positions: Tuple[int, ...]   # sorted positions of reading nodes
    free_pos: Optional[int]          # position after which it can be freed

    @property
    def first_use(self) -> Optional[int]:
        return self.use_positions[0] if self.use_positions else None

    @property
    def last_use(self) -> Optional[int]:
        return self.use_positions[-1] if self.use_positions else None

    def idle_gaps(self) -> List[Tuple[int, int]]:
        """(start_pos, end_pos) intervals where the tensor is resident but
        unused: birth→first use and between consecutive uses."""
        gaps: List[Tuple[int, int]] = []
        birth = self.producer_pos if self.producer_pos is not None else -1
        prev = birth
        for u in self.use_positions:
            if u - prev > 1:
                gaps.append((prev, u))
            prev = u
        return gaps

    def longest_gap(self) -> Tuple[int, int]:
        gaps = self.idle_gaps()
        if not gaps:
            return (0, 0)
        return max(gaps, key=lambda g: g[1] - g[0])


def analyze(graph: Graph, order: Optional[Sequence[str]] = None) -> Dict[str, Lifetime]:
    """Lifetime of every tensor under ``order`` (cache ops excluded from
    'uses' — only compute reads count as uses)."""
    order = list(order) if order is not None else graph.order()
    pos = {n: i for i, n in enumerate(order)}
    producer: Dict[str, Optional[int]] = {t: None for t in graph.tensors}
    uses: Dict[str, List[int]] = {t: [] for t in graph.tensors}
    for name in order:
        node = graph.nodes[name]
        if node.kind != "compute":
            continue
        for t in node.outputs:
            if producer[t] is None:
                producer[t] = pos[name]
        for t in node.inputs:
            uses[t].append(pos[name])
    out: Dict[str, Lifetime] = {}
    for t, info in graph.tensors.items():
        u = tuple(sorted(uses[t]))
        persistent = info.klass in ("weight", "state")
        free = None if persistent or not u else u[-1]
        out[t] = Lifetime(t, info.nbytes, info.klass, producer[t], u, free)
    return out
