"""Logical sharding assignment for parameter / optimizer / cache pytrees.

Leaves are matched by their final key-path name and mapped to logical axis
tuples; ``sharding.rules.logical_spec`` resolves those against the active
mesh, dropping any axis that does not divide evenly (GQA kv=8 on a 16-way
model axis, 40 experts, batch=1, ...). Extra *leading* dimensions (the
stacked-layers axis from the segment scan) are padded with the "layers"
logical name (unsharded).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import AxisRules, logical_spec

# final-path-key -> logical names for the *trailing* dims
PARAM_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # attention
    "wq": ("embed", "q_dim"),
    "wk": ("embed", "kv_dim"),
    "wv": ("embed", "kv_dim"),
    "wo": ("q_dim", "embed"),
    "xwq": ("embed", "q_dim"),
    "xwk": ("embed", "kv_dim"),
    "xwv": ("embed", "kv_dim"),
    "xwo": ("q_dim", "embed"),
    # MLA
    "wdq": ("embed", "lora"),
    "wuq": ("lora", "q_dim"),
    "wdkv": ("embed", "lora"),
    "wkr": ("embed", None),
    "wukv": ("lora", "q_dim"),
    "q_norm": (None,),
    "kv_norm": (None,),
    # dense mlp
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
    "w_in": ("embed", "mlp"),
    "w_out": ("mlp", "embed"),
    # router (E small — replicated)
    "router": ("embed", None),
    # mamba2
    "in_proj": ("embed", "ssm_inner"),
    "out_proj": ("ssm_inner", "embed"),
    "conv_w": (None, "ssm_inner"),
    "conv_b": ("ssm_inner",),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "dt_bias": ("ssm_heads",),
    "gate_norm": ("ssm_inner",),
    # norms
    "scale": (None,),
    "bias": (None,),
}

# MoE expert tensors are 3-D (E, ·, ·): expert dim first
MOE_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "w_gate": ("experts", "embed", "expert_mlp"),
    "w_up": ("experts", "embed", "expert_mlp"),
    "w_down": ("experts", "expert_mlp", "embed"),
}

CACHE_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("cache_batch", "cache_seq", "cache_heads", None),
    "v": ("cache_batch", "cache_seq", "cache_heads", None),
    "xk": ("cache_batch", "frames", "cache_heads", None),
    "xv": ("cache_batch", "frames", "cache_heads", None),
    "ckv": ("cache_batch", "cache_seq", None),
    "krope": ("cache_batch", "cache_seq", None),
    "conv": ("cache_batch", "ssm_inner", None),
    "ssm": ("cache_batch", "ssm_heads", None, None),
}

BATCH_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", None),
    "targets": ("batch", None),
    "token": ("batch", None),
    "enc_embeds": ("batch", None, None),
    "vision_embeds": ("batch", None, None),
    "vision_mask": ("batch", None),
    "positions": (None, "batch", None),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
    return ""


def _spec_for(path, leaf, table: Dict[str, Tuple[Optional[str], ...]],
              rules: AxisRules, mesh: Mesh) -> P:
    name = _leaf_name(path)
    keys = [str(getattr(p, "key", "")) for p in path]
    logical = None
    if name in MOE_LOGICAL and leaf.ndim - _lead(leaf, MOE_LOGICAL[name]) >= 0 \
            and "ffn" in keys and leaf.ndim >= 3:
        cand = MOE_LOGICAL[name]
        if leaf.ndim >= len(cand):
            logical = cand
    if logical is None:
        logical = table.get(name)
    if logical is None:
        return P()
    lead = leaf.ndim - len(logical)
    if lead < 0:
        return P()
    names = ("layers",) * lead + tuple(logical)
    return logical_spec(leaf.shape, names, rules, mesh)


def _lead(leaf, logical):
    return leaf.ndim - len(logical)


def param_shardings(params_spec: Any, mesh: Mesh, rules: AxisRules) -> Any:
    """NamedShardings for a params (or optimizer-moments) pytree spec."""
    def f(path, leaf):
        return NamedSharding(mesh, _spec_for(path, leaf, PARAM_LOGICAL, rules, mesh))
    return jax.tree_util.tree_map_with_path(f, params_spec)


def cache_shardings(cache_spec: Any, mesh: Mesh, rules: AxisRules) -> Any:
    def f(path, leaf):
        return NamedSharding(mesh, _spec_for(path, leaf, CACHE_LOGICAL, rules, mesh))
    return jax.tree_util.tree_map_with_path(f, cache_spec)


def batch_shardings(batch_spec: Any, mesh: Mesh, rules: AxisRules) -> Any:
    def f(path, leaf):
        return NamedSharding(mesh, _spec_for(path, leaf, BATCH_LOGICAL, rules, mesh))
    return jax.tree_util.tree_map_with_path(f, batch_spec)
