"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
device initialization.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod ("data", "model"); the multi-pod variant is
    2×16×16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh on however many devices exist (tests)."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)
