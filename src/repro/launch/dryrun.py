import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Backend (per-device codegen) optimization adds minutes per compile on this
# single-core host but does not change SPMD partitioning, collectives, or
# buffer assignment — verified: identical roofline terms and memory analysis
# at level 0. The dry-run only consumes those artifacts.
os.environ["XLA_FLAGS"] += " --xla_backend_optimization_level=0"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes with ShapeDtypeStruct stand-ins (no allocation).

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape train_4k [--multi-pod] [--out results/dryrun.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The two XLA_FLAGS lines above MUST precede every other import — JAX locks
the device count at first initialization.

Per combination this prints/records: memory_analysis (bytes per device —
proves it fits), cost_analysis FLOPs/bytes, the parsed collective schedule,
and the three roofline terms (§Roofline of EXPERIMENTS.md).
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, REGISTRY, InputShape, ModelConfig
from repro.data.pipeline import make_batch_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import batch_shardings, cache_shardings, param_shardings
from repro.models.model import build_model
from repro.optim.adamw import AdamWState
from repro.roofline.analysis import roofline_from_compiled
from repro.sharding.rules import DEFAULT_RULES, MULTIPOD_RULES, axis_rules
from repro.training.step import TrainStepConfig, make_train_step


def _rules_for(cfg: ModelConfig, shape: InputShape, mesh) -> Dict:
    rules = dict(MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES)
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if shape.kind in ("train", "prefill"):
        # Megatron-style sequence parallelism: the residual stream (and the
        # per-layer activations saved for backward) stay seq-sharded over the
        # model axis between layers — 16× smaller saved activations
        rules["seq_act"] = ("model",)
    if shape.kind == "decode" and cfg.n_kv_heads % model_size != 0:
        # kv heads don't divide the model axis — shard the cache sequence
        # dimension instead (XLA gathers K/V per layer; see EXPERIMENTS.md)
        rules["cache_seq"] = ("model",)
        rules["cache_heads"] = None
    return rules


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              memory_mode: str = "offload", compile_: bool = True,
              cfg_override: Optional[ModelConfig] = None,
              rules_override: Optional[Dict] = None) -> Dict:
    cfg = cfg_override if cfg_override is not None else REGISTRY[arch]
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model = build_model(cfg, shape)
    rules = _rules_for(cfg, shape, mesh)
    if rules_override:
        rules.update(rules_override)
    dtype = jnp.bfloat16

    rec: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "memory_mode": memory_mode,
        "swa_variant": model.swa_override is not None,
    }
    t0 = time.time()
    with axis_rules(rules, mesh), mesh:
        param_spec = model.param_specs(dtype)
        p_shard = param_shardings(param_spec, mesh, rules)

        if shape.kind == "train":
            batch_spec = make_batch_specs(cfg, shape.seq_len, shape.global_batch, dtype)
            b_shard = batch_shardings(batch_spec, mesh, rules)
            opt_spec = AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                mu=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_spec),
                nu=jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_spec),
            )
            o_shard = AdamWState(
                step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                mu=param_shardings(opt_spec.mu, mesh, rules),
                nu=param_shardings(opt_spec.nu, mesh, rules),
            )
            ts = TrainStepConfig(
                remat="offload" if memory_mode == "offload" else "full")
            step = make_train_step(model, ts, jit=False)
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(param_spec, opt_spec, batch_spec)
            tokens = shape.global_batch * shape.seq_len

        elif shape.kind == "prefill":
            batch_spec = model.input_specs(shape, dtype)
            b_shard = batch_shardings(batch_spec, mesh, rules)
            cache_spec = model.cache_specs(shape.global_batch, shape.seq_len, dtype)
            c_shard = cache_shardings(cache_spec, mesh, rules)

            def prefill_step(params, batch, cache):
                return model.prefill(params, batch, cache)

            jitted = jax.jit(prefill_step,
                             in_shardings=(p_shard, b_shard, c_shard),
                             donate_argnums=(2,))
            lowered = jitted.lower(param_spec, batch_spec, cache_spec)
            tokens = shape.global_batch * shape.seq_len

        else:  # decode: ONE new token against a seq_len KV cache
            cache_spec = model.cache_specs(shape.global_batch, shape.seq_len, dtype)
            c_shard = cache_shardings(cache_spec, mesh, rules)
            tok_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tok_shard = batch_shardings({"token": tok_spec}, mesh, rules)["token"]
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            pos_shard = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

            def serve_step(params, cache, token, pos):
                return model.decode_step(params, cache, token, pos)

            jitted = jax.jit(serve_step,
                             in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(param_spec, cache_spec, tok_spec, pos_spec)
            tokens = shape.global_batch

        rec["lower_s"] = round(time.time() - t0, 2)
        if not compile_:
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "peak_gb": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                        + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 1e9,
        }
        terms = roofline_from_compiled(compiled, cfg, tokens, n_dev,
                                       train=(shape.kind == "train"))
        rec["roofline"] = terms.row()
        rec["collectives"] = terms.coll_breakdown
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape), single-pod + multi-pod")
    ap.add_argument("--memory-mode", choices=("offload", "baseline"),
                    default="offload")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for arch in REGISTRY:
            for shape in INPUT_SHAPES:
                combos.append((arch, shape, False))
                combos.append((arch, shape, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    records = []
    for arch, shape, mp in combos:
        tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
        try:
            rec = lower_one(arch, shape, multi_pod=mp,
                            memory_mode=args.memory_mode)
            records.append(rec)
            r = rec.get("roofline", {})
            print(f"OK   {tag}: peak {rec['memory_analysis']['peak_gb']:.2f} GB/dev, "
                  f"compute {r.get('compute_s', 0):.4f}s mem {r.get('memory_s', 0):.4f}s "
                  f"coll {r.get('collective_s', 0):.4f}s → {r.get('dominant')}")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"FAIL {tag}: {type(e).__name__}: {e}")
            records.append({"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if mp else "16x16",
                            "error": f"{type(e).__name__}: {e}"})
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        with open(args.out, "w") as f:
            json.dump(existing + records, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
