"""Serving launcher: batched generation through the `repro.api` front door.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --prompt-len 32 --new-tokens 32 --batch 4 [--mode kv_offload]

``--mode`` selects the `OffloadConfig` mode. ``--remote-bw GB/s`` swaps
the default topology's remote tier for a bandwidth-throttled modeled tier
(the paper's Fig. 6 D2H sweep, one point per invocation), and
``--recalibrate`` re-runs the generation after feeding the measured
per-tier-pair bandwidths back into planning.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import HyperOffloadSession, OffloadConfig
from repro.configs import REGISTRY
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model
from repro.pool import TierTopology, sweep_topologies


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    # this launcher drives ServeEngine only — the paged/continuous modes
    # live in examples/serve_offload.py and benchmarks/serve_continuous.py
    ap.add_argument("--mode", choices=("resident", "kv_offload"),
                    default="resident")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remote-bw", type=float, default=None, metavar="GBPS",
                    help="throttle the remote tier's read bandwidth to this "
                         "many GB/s (modeled tier; Fig.-6-style sweep point)")
    ap.add_argument("--recalibrate", action="store_true",
                    help="after the run, replan from measured per-tier-pair "
                         "bandwidths and generate once more")
    args = ap.parse_args(argv)
    mode = args.mode

    cfg = REGISTRY[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    data = SyntheticTokens(cfg.vocab_size, seq_len=args.prompt_len,
                           global_batch=args.batch, seed=args.seed)
    batch = data.batch(0, cfg)
    batch.pop("targets", None)

    topology = None
    if args.remote_bw is not None:
        topology, = sweep_topologies(
            TierTopology.default(), "remote",
            read_bws=[args.remote_bw * 1e9])
    config = OffloadConfig(mode=mode, max_batch=args.batch,
                           max_seq=args.prompt_len + args.new_tokens,
                           topology=topology)
    with HyperOffloadSession(config) as session:
        engine = session.serve_engine(model, params)
        t0 = time.time()
        out = engine.generate(batch, args.new_tokens,
                              temperature=args.temperature, seed=args.seed)
        dt = time.time() - t0
        toks = args.batch * args.new_tokens
        print(f"arch={cfg.name} mode={mode} "
              f"tiers={'/'.join(session.pool.spill_order)} "
              f"generated {out.shape} in {dt:.2f}s ({toks/dt:.1f} tok/s)")
        print("first sequence:", out[0].tolist())
        s = session.stats()
        print(f"stats: {s['serve']} pool_puts={s['pool']['puts']} "
              f"pool_gets={s['pool']['gets']}")
        if args.recalibrate:
            spec = session.recalibrate()
            t0 = time.time()
            out = engine.generate(batch, args.new_tokens,
                                  temperature=args.temperature,
                                  seed=args.seed)
            dt2 = time.time() - t0
            print(f"recalibrated hw={spec.name} "
                  f"d2r={spec.pool_bw_d2r:.3g}B/s r2d={spec.pool_bw_r2d:.3g}B/s "
                  f"rerun {dt2:.2f}s ({toks/dt2:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
