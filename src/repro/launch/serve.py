"""Serving launcher: batched generation with optional KV-cache offload.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \
        --prompt-len 32 --new-tokens 32 --batch 4 [--offload-kv]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model
from repro.serving.engine import ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--offload-kv", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = REGISTRY[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    data = SyntheticTokens(cfg.vocab_size, seq_len=args.prompt_len,
                           global_batch=args.batch, seed=args.seed)
    batch = data.batch(0, cfg)
    batch.pop("targets", None)

    max_seq = args.prompt_len + args.new_tokens
    engine = ServeEngine(model, params, max_seq=max_seq,
                         offload_kv=args.offload_kv)
    t0 = time.time()
    out = engine.generate(batch, args.new_tokens,
                          temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name} offload_kv={args.offload_kv} "
          f"generated {out.shape} in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print("first sequence:", out[0].tolist())
    print(f"stats: {engine.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
