"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --steps 50 --remat offload --offload-opt-state

``--smoke`` trains the reduced config on however many local devices exist;
without it the full config is used (requires real accelerators — on this
CPU host the full configs only lower via launch/dryrun.py). Checkpoints go
to --ckpt-dir every --ckpt-every steps; training resumes from the latest
checkpoint if one exists.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.api import HyperOffloadSession, OffloadConfig
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.configs import REGISTRY
from repro.data.pipeline import SyntheticTokens
from repro.models.model import build_model
from repro.optim.adamw import AdamWState


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(REGISTRY), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--remat", choices=("none", "full", "offload"), default="none")
    ap.add_argument("--offload-opt-state", action="store_true")
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = REGISTRY[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    # the memory policy (remat / optimizer-state offload) lives in the
    # session config; optimization hyperparameters override per run
    session = HyperOffloadSession(OffloadConfig(
        mode="resident", remat=args.remat,
        offload_opt_state=args.offload_opt_state))
    ts = session.train_config(peak_lr=args.peak_lr,
                              warmup=max(1, args.steps // 10),
                              total_steps=args.steps)
    params, opt_state = session.init_train_state(
        model, jax.random.key(args.seed), ts=ts)
    step_fn = session.train_step(model, ts)
    data = SyntheticTokens(cfg.vocab_size, seq_len=args.seq_len,
                           global_batch=args.batch, seed=args.seed, noise=0.05)

    start = 0
    if args.ckpt_dir:
        latest = os.path.join(args.ckpt_dir, "latest.npz")
        if os.path.exists(latest):
            params, start = load_checkpoint(latest, params)
            print(f"resumed from {latest} at step {start}")

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M remat={args.remat} "
          f"opt_offload={args.offload_opt_state}")
    t0 = time.time()
    for i in range(start, args.steps):
        batch = data.batch(i, cfg)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  ({dt:.1f}s)")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(os.path.join(args.ckpt_dir, "latest.npz"), params, i + 1)
    final_loss = float(metrics["loss"])
    print(f"done: final loss {final_loss:.4f}")
    session.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
