"""Mamba2 (SSD — state-space duality) mixer [arXiv:2405.21060].

Full-sequence path uses the chunked SSD algorithm (intra-chunk quadratic
blocks + inter-chunk state recurrence); the decode path is the O(1)
per-token recurrence. Both share parameters and agree numerically
(tests/test_ssm.py asserts full-vs-recurrent equivalence).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rmsnorm
from repro.sharding.rules import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params & cache
# ---------------------------------------------------------------------------


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_ssm_heads(cfg.d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return s, di, nh, conv_dim


def init_mamba_params(cfg: ModelConfig, key, dtype) -> Dict:
    s, di, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + nh
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # dt bias such that softplus(dt_bias) spans ~[1e-3, 1e-1] (mamba default)
    u = jax.random.uniform(k3, (nh,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    a_init = jnp.log(1.0 + 15.0 * jax.random.uniform(k4, (nh,), jnp.float32))
    return {
        "in_proj": dense_init(k1, (d, in_dim), dtype),
        "conv_w": 0.1 * jax.random.normal(k2, (s.d_conv, conv_dim), jnp.float32).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": a_init,
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "gate_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(k5, (di, d), dtype),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    s, di, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, conv_dim, s.d_conv - 1), dtype),
        "ssm": jnp.zeros((batch, nh, s.headdim, s.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Chunked SSD (full sequence)
# ---------------------------------------------------------------------------


def segsum(a: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L); out[i, j] = sum_{k=j+1..i} a_k for i>=j else -inf."""
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ln = a.shape[-1]
    mask = jnp.arange(ln)[:, None] >= jnp.arange(ln)[None, :]
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jax.Array,    # (B, S, H, P)   already scaled by dt
    a: jax.Array,    # (B, S, H)      = dt * A   (negative)
    b_mat: jax.Array,  # (B, S, H, N)
    c_mat: jax.Array,  # (B, S, H, N)
    chunk: int,
    init_state: jax.Array = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, chunk, h, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, nc, chunk, h, n).astype(jnp.float32)
    ac = a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2).astype(jnp.float32)  # (B,H,C,L)
    a_cumsum = jnp.cumsum(ac, axis=-1)                                 # (B,H,C,L)

    # 1) intra-chunk (diagonal blocks)
    l_mat = jnp.exp(segsum(ac))                                        # (B,H,C,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", cc, bc, l_mat, xc)

    # 2) per-chunk final states
    decay_states = jnp.exp(a_cumsum[..., -1:] - a_cumsum)              # (B,H,C,L)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bc, decay_states, xc)

    # 3) inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    states = jnp.concatenate([init_state[:, None].transpose(0, 1, 2, 3, 4), states], axis=1)
    chunk_sums = jnp.pad(a_cumsum[..., -1], ((0, 0), (0, 0), (1, 0)))  # (B,H,C+1)
    decay_chunk = jnp.exp(segsum(chunk_sums))                          # (B,H,C+1,C+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", decay_chunk, states)
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4) state -> output
    state_decay_out = jnp.exp(a_cumsum)                                # (B,H,C,L)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (K, C) depthwise causal conv via shifted adds."""
    k = w.shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xi.astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def _project(cfg: ModelConfig, p: Dict, x: jax.Array):
    s, di, nh, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_dim]
    dt_raw = zxbcdt[..., di + conv_dim :]
    return z, xbc, dt_raw


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    s, di, nh, conv_dim = _dims(cfg)
    g, n = s.n_groups, s.d_state
    xs = xbc[..., :di]
    b_mat = xbc[..., di : di + g * n]
    c_mat = xbc[..., di + g * n :]
    shape = xbc.shape[:-1]
    heads_per_group = nh // g
    b_mat = b_mat.reshape(*shape, g, n)
    c_mat = c_mat.reshape(*shape, g, n)
    # broadcast groups to heads
    b_mat = jnp.repeat(b_mat, heads_per_group, axis=-2)
    c_mat = jnp.repeat(c_mat, heads_per_group, axis=-2)
    return xs, b_mat, c_mat


def mamba_forward(cfg: ModelConfig, p: Dict, x: jax.Array,
                  use_kernel: Optional[bool] = None) -> jax.Array:
    """Full-sequence Mamba2 block. x: (B, S, D) -> (B, S, D)."""
    if use_kernel is None:
        from repro.models import runtime
        use_kernel = runtime.attention_impl() == "pallas"
    s_cfg, di, nh, conv_dim = _dims(cfg)
    bsz, slen, _ = x.shape
    z, xbc, dt_raw = _project(cfg, p, x)
    xbc = jax.nn.silu(causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, b_mat, c_mat = _split_xbc(cfg, xbc)
    xs = xs.reshape(bsz, slen, nh, s_cfg.headdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    a = -jnp.exp(p["A_log"])                                           # (H,)
    x_dt = xs.astype(jnp.float32) * dt[..., None]
    a_dt = dt * a[None, None, :]
    # pad sequence to a chunk multiple
    chunk = min(s_cfg.chunk_size, slen)
    pad = (-slen) % chunk
    if pad:
        x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if use_kernel:
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(x_dt, a_dt, b_mat, c_mat, chunk)
    else:
        y, _ = ssd_chunked(x_dt, a_dt, b_mat, c_mat, chunk)
    y = y[:, :slen]
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, slen, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    y = constrain(y, ("batch", "seq", "ssm_inner"))
    return y @ p["out_proj"]


def mamba_prefill(cfg: ModelConfig, p: Dict, x: jax.Array,
                  cache: Dict) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward that also produces the recurrent cache."""
    s_cfg, di, nh, conv_dim = _dims(cfg)
    bsz, slen, _ = x.shape
    z, xbc, dt_raw = _project(cfg, p, x)
    xbc_conv = jax.nn.silu(causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, b_mat, c_mat = _split_xbc(cfg, xbc_conv)
    xs = xs.reshape(bsz, slen, nh, s_cfg.headdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    x_dt = xs.astype(jnp.float32) * dt[..., None]
    a_dt = dt * a[None, None, :]
    chunk = min(s_cfg.chunk_size, slen)
    pad = (-slen) % chunk
    if pad:
        x_dt = jnp.pad(x_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_dt = jnp.pad(a_dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, final_state = ssd_chunked(x_dt, a_dt, b_mat, c_mat, chunk)
    y = y[:, :slen]
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, slen, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    # conv cache: last (d_conv - 1) *pre-activation* conv inputs
    k = s_cfg.d_conv - 1
    tail = xbc[:, -k:, :] if slen >= k else jnp.pad(xbc, ((0, 0), (k - slen, 0), (0, 0)))
    new_cache = {
        "conv": tail.transpose(0, 2, 1).astype(cache["conv"].dtype),
        "ssm": final_state,
    }
    return out, new_cache


def mamba_decode(cfg: ModelConfig, p: Dict, x: jax.Array,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent step. x: (B, 1, D)."""
    s_cfg, di, nh, conv_dim = _dims(cfg)
    bsz = x.shape[0]
    z, xbc, dt_raw = _project(cfg, p, x)           # (B,1,·)
    z, xbc, dt_raw = z[:, 0], xbc[:, 0], dt_raw[:, 0]
    # conv over the stored window + current token
    window = jnp.concatenate([cache["conv"], xbc[:, :, None].astype(cache["conv"].dtype)
                              .transpose(0, 1, 2)], axis=2)  # (B, C, K)
    w = p["conv_w"].astype(jnp.float32)            # (K, C)
    conv_out = jnp.sum(window.astype(jnp.float32) * w.T[None], axis=-1) + p["conv_b"].astype(jnp.float32)
    xbc_act = jax.nn.silu(conv_out).astype(x.dtype)  # (B, C)
    xs, b_mat, c_mat = _split_xbc(cfg, xbc_act)
    xs = xs.reshape(bsz, nh, s_cfg.headdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a[None, :])                   # (B,H)
    state = cache["ssm"] * da[..., None, None]
    state = state + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt, b_mat.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", c_mat.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = {"conv": window[..., 1:], "ssm": state}
    return out, new_cache
