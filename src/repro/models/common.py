"""Shared building blocks: norms, rotary embeddings (RoPE / M-RoPE), init."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0):
    """Truncated-normal fan-in init (MaxText-style)."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def embed_init(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.02


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) parameterisation keeps zero-init neutral
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(dtype)


def norm_params(cfg: ModelConfig, key) -> dict:
    if cfg.norm == "layernorm":
        return {
            "scale": jnp.zeros((cfg.d_model,), jnp.float32),
            "bias": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embedding, shape (head_dim // 2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """Rotate ``x`` of shape (..., S, H, D) by ``positions``.

    positions: (B, S) for standard RoPE, or (3, B, S) for M-RoPE
    [arXiv:2409.12191] where the three planes carry temporal/height/width
    coordinates and ``mrope_sections`` partitions the D//2 frequency channels.
    """
    d = x.shape[-1]
    half = d // 2
    inv = rope_freqs(d, theta)  # (half,)
    if mrope_sections is not None:
        assert positions.ndim == 3 and positions.shape[0] == 3, positions.shape
        assert sum(mrope_sections) == half, (mrope_sections, half)
        # per-channel section id -> select the matching position plane
        sec_id = jnp.repeat(
            jnp.arange(3), jnp.array(mrope_sections), total_repeat_length=half
        )  # (half,)
        sec_onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)  # (half, 3)
        pos = positions.astype(jnp.float32)  # (3, B, S)
        ang_all = pos[..., None] * inv[None, None, None, :]  # (3, B, S, half)
        ang = jnp.einsum("pbsh,hp->bsh", ang_all, sec_onehot)  # (B, S, half)
    else:
        pos = positions.astype(jnp.float32)  # (B, S)
        ang = pos[..., None] * inv[None, None, :]  # (B, S, half)
    sin = jnp.sin(ang)[..., None, :]  # (B, S, 1, half)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
