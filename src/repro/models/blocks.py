"""Layer (block) application: pre-norm residual structure over a mixer and an
FFN, with gemma2-style optional post-sublayer norms and whisper-style
cross-attention sublayers. One code path per execution mode (train-forward,
prefill, decode) so caches stay explicit."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_norm, norm_params
from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer_params(cfg: ModelConfig, spec: LayerSpec, key, dtype) -> Dict:
    k_mix, k_ffn, k_norm = jax.random.split(key, 3)
    p: Dict = {"pre_norm": norm_params(cfg, k_norm)}
    if spec.mixer == "mamba2":
        p["mixer"] = ssm_mod.init_mamba_params(cfg, k_mix, dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn.init_mla_params(cfg, k_mix, dtype)
    else:
        p["mixer"] = attn.init_attn_params(cfg, spec, k_mix, dtype)
    if spec.post_norms:
        p["post_norm"] = norm_params(cfg, k_norm)
    if spec.cross_attn:
        p["cross_norm"] = norm_params(cfg, k_norm)
    if spec.ffn != "none":
        p["ffn_norm"] = norm_params(cfg, k_norm)
        if spec.ffn == "moe":
            p["ffn"] = moe_mod.init_moe_params(cfg, k_ffn, dtype)
        elif spec.ffn == "gelu":
            p["ffn"] = mlp_mod.init_gelu_params(cfg, k_ffn, dtype)
        else:
            p["ffn"] = mlp_mod.init_swiglu_params(cfg, k_ffn, dtype)
        if spec.post_norms:
            p["post_ffn_norm"] = norm_params(cfg, k_norm)
    return p


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_seq: int, dtype,
                     swa_override: Optional[int] = None,
                     enc_frames: Optional[int] = None) -> Dict:
    if spec.mixer == "mamba2":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    return attn.init_attn_cache(cfg, spec, batch, max_seq, dtype,
                                swa_override=swa_override,
                                enc_frames=enc_frames)


# ---------------------------------------------------------------------------
# Forward (training — no cache)
# ---------------------------------------------------------------------------


def apply_layer(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    enc_out: Optional[jax.Array] = None,
    causal: bool = True,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["pre_norm"], x)
    if spec.mixer == "mamba2":
        h = ssm_mod.mamba_forward(cfg, p["mixer"], h)
    else:
        h = attn.attention_full(cfg, spec, p["mixer"], h, positions,
                                causal=causal, swa_override=swa_override)
    if spec.post_norms:
        h = apply_norm(cfg, p["post_norm"], h)
    # seq-shard the sublayer output BEFORE the residual add: the row-parallel
    # wo matmul's all-reduce becomes a reduce-scatter (Megatron-SP), and the
    # saved "attn_out" tensor is 1/TP the size
    h = constrain(h, ("batch", "seq_act", "embed_act"))
    h = checkpoint_name(h, "attn_out")
    x = x + h
    if spec.cross_attn and enc_out is not None:
        h = apply_norm(cfg, p["cross_norm"], x)
        x = x + attn.cross_attention_full(cfg, p["mixer"], h, enc_out)
    if spec.ffn != "none":
        h = apply_norm(cfg, p["ffn_norm"], x)
        if spec.ffn == "moe":
            h, aux = moe_mod.moe_ffn(cfg, p["ffn"], h)
        elif spec.ffn == "gelu":
            h = mlp_mod.gelu_mlp(p["ffn"], h)
        else:
            h = mlp_mod.swiglu(p["ffn"], h)
        if spec.post_norms:
            h = apply_norm(cfg, p["post_ffn_norm"], h)
        h = constrain(h, ("batch", "seq_act", "embed_act"))
        h = checkpoint_name(h, "mlp_out")
        x = x + h
    x = constrain(x, ("batch", "seq_act", "embed_act"))
    x = checkpoint_name(x, "resid")
    return x, aux


# ---------------------------------------------------------------------------
# Prefill (forward + cache build)
# ---------------------------------------------------------------------------


def apply_layer_prefill(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    cache: Dict,
    *,
    enc_out: Optional[jax.Array] = None,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, Dict]:
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["pre_norm"], x)
    if spec.mixer == "mamba2":
        h, new_cache = ssm_mod.mamba_prefill(cfg, p["mixer"], h, cache)
    else:
        h, new_cache = attn.attention_prefill(
            cfg, spec, p["mixer"], h, positions, cache,
            swa_override=swa_override, enc_out=enc_out)
    if spec.post_norms:
        h = apply_norm(cfg, p["post_norm"], h)
    x = x + h
    if spec.cross_attn and enc_out is not None:
        h = apply_norm(cfg, p["cross_norm"], x)
        x = x + attn.cross_attention_full(cfg, p["mixer"], h, enc_out)
    if spec.ffn != "none":
        h = apply_norm(cfg, p["ffn_norm"], x)
        if spec.ffn == "moe":
            h, aux = moe_mod.moe_ffn(cfg, p["ffn"], h)
        elif spec.ffn == "gelu":
            h = mlp_mod.gelu_mlp(p["ffn"], h)
        else:
            h = mlp_mod.swiglu(p["ffn"], h)
        if spec.post_norms:
            h = apply_norm(cfg, p["post_ffn_norm"], h)
        x = x + h
    x = constrain(x, ("batch", "seq_act", "embed_act"))
    return x, aux, new_cache


def apply_layer_prefill_chunk(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,            # (B, S_chunk, D)
    offset: jax.Array,       # scalar: global position of chunk token 0
    positions: jax.Array,    # (B, S_chunk) or (3, B, S_chunk)
    valid_len: jax.Array,    # scalar: real tokens in the chunk
    cache: Dict,
    *,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, Dict]:
    """Chunked cache-aware prefill step for one layer: the chunk attends
    over [cache ++ chunk] at its position offset and the cache advances by
    the chunk's (valid) K/V. Attention/MLA mixers only — recurrent (mamba2)
    and cross-attention layers have no per-position cache to resume from
    (``Model.supports_chunked_prefill`` gates this upstream)."""
    if spec.mixer == "mamba2" or spec.cross_attn:
        raise NotImplementedError(
            "chunked prefill supports attention/MLA self-attention layers "
            "only (gate on Model.supports_chunked_prefill)")
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["pre_norm"], x)
    h, new_cache = attn.attention_prefill_chunk(
        cfg, spec, p["mixer"], h, offset, positions, valid_len, cache,
        swa_override=swa_override)
    if spec.post_norms:
        h = apply_norm(cfg, p["post_norm"], h)
    x = x + h
    if spec.ffn != "none":
        h = apply_norm(cfg, p["ffn_norm"], x)
        if spec.ffn == "moe":
            h, aux = moe_mod.moe_ffn(cfg, p["ffn"], h)
        elif spec.ffn == "gelu":
            h = mlp_mod.gelu_mlp(p["ffn"], h)
        else:
            h = mlp_mod.swiglu(p["ffn"], h)
        if spec.post_norms:
            h = apply_norm(cfg, p["post_ffn_norm"], h)
        x = x + h
    x = constrain(x, ("batch", "seq_act", "embed_act"))
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Decode (single token)
# ---------------------------------------------------------------------------


def apply_layer_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,            # (B, 1, D)
    pos: jax.Array,          # scalar
    positions: jax.Array,    # (B,1) or (3,B,1)
    cache: Dict,
    *,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, Dict]:
    h = apply_norm(cfg, p["pre_norm"], x)
    if spec.mixer == "mamba2":
        h, new_cache = ssm_mod.mamba_decode(cfg, p["mixer"], h, cache)
    else:
        h, new_cache = attn.attention_decode(
            cfg, spec, p["mixer"], h, pos, positions, cache,
            swa_override=swa_override)
    if spec.post_norms:
        h = apply_norm(cfg, p["post_norm"], h)
    x = x + h
    if spec.cross_attn:
        h = apply_norm(cfg, p["cross_norm"], x)
        x = x + attn.cross_attention_decode(cfg, p["mixer"], h, cache)
    if spec.ffn != "none":
        h = apply_norm(cfg, p["ffn_norm"], x)
        if spec.ffn == "moe":
            h, _ = moe_mod.moe_ffn(cfg, p["ffn"], h)
        elif spec.ffn == "gelu":
            h = mlp_mod.gelu_mlp(p["ffn"], h)
        else:
            h = mlp_mod.swiglu(p["ffn"], h)
        if spec.post_norms:
            h = apply_norm(cfg, p["post_ffn_norm"], h)
        x = x + h
    return x, new_cache
