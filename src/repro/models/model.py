"""Public model facade: build once from a ModelConfig, then call
``loss`` / ``forward`` / ``prefill`` / ``decode_step`` / ``input_specs``.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of
a workload shape (the dry-run pattern: weak-type-correct, shardable, no
device allocation). Modality frontends are stubs: audio supplies
``enc_embeds`` (precomputed conv/mel frames), vision supplies aligned
``vision_embeds`` + ``vision_mask`` and M-RoPE ``positions``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as tfm


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean cross-entropy in f32. logits (B,S,V), targets (B,S) int32."""
    logz = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logz, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    swa_override: Optional[int] = None

    # -- init ---------------------------------------------------------------
    def init(self, key, dtype=jnp.float32) -> Dict:
        return tfm.init_params(self.cfg, key, dtype)

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.float32) -> Dict:
        return tfm.init_cache(self.cfg, batch, max_seq, dtype,
                              swa_override=self.swa_override)

    # -- training -----------------------------------------------------------
    def forward(self, params: Dict, batch: Dict, remat_policy=None) -> Tuple[jax.Array, jax.Array]:
        return tfm.forward(
            self.cfg, params, batch["tokens"],
            positions=batch.get("positions"),
            enc_embeds=batch.get("enc_embeds"),
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"),
            swa_override=self.swa_override,
            remat_policy=remat_policy,
        )

    def loss(self, params: Dict, batch: Dict, remat_policy=None) -> jax.Array:
        logits, aux = self.forward(params, batch, remat_policy=remat_policy)
        return _xent(logits, batch["targets"]) + aux

    # -- inference ----------------------------------------------------------
    def prefill(self, params: Dict, batch: Dict, cache: Dict) -> Tuple[jax.Array, Dict]:
        return tfm.prefill(
            self.cfg, params, batch["tokens"], cache,
            positions=batch.get("positions"),
            enc_embeds=batch.get("enc_embeds"),
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"),
            swa_override=self.swa_override,
        )

    def prefill_chunk(self, params: Dict, batch: Dict, offset: jax.Array,
                      valid_len: jax.Array, cache: Dict) -> Tuple[jax.Array, Dict]:
        """Cache-aware prefill of one prompt chunk at a global position
        offset (see ``transformer.prefill_chunk``). Only the first
        ``valid_len`` tokens of the chunk are real; logits are the last
        valid token's. Requires ``supports_chunked_prefill``."""
        return tfm.prefill_chunk(
            self.cfg, params, batch["tokens"], offset, valid_len, cache,
            swa_override=self.swa_override)

    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill resumes from a per-position KV cache; recurrent
        (mamba2) mixers, cross-attention layers, and encoder frontends have
        state the chunk path cannot yet carry."""
        return self.cfg.encoder is None and all(
            spec.mixer in ("attn", "mla") and not spec.cross_attn
            for seg in self.cfg.segments for spec in seg.pattern)

    def decode_step(self, params: Dict, cache: Dict, token: jax.Array,
                    pos: jax.Array, inplace: bool = True) -> Tuple[jax.Array, Dict]:
        return tfm.decode_step(self.cfg, params, cache, token, pos,
                               swa_override=self.swa_override, inplace=inplace)

    # -- dry-run specs --------------------------------------------------------
    def param_specs(self, dtype=jnp.bfloat16) -> Any:
        return jax.eval_shape(lambda k: self.init(k, dtype),
                              jax.random.key(0))

    def cache_specs(self, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Any:
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_seq, dtype))

    def input_specs(self, shape: InputShape, dtype=jnp.bfloat16) -> Dict:
        """ShapeDtypeStruct stand-ins for the workload batch."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {
                "tokens": sds((b, s), jnp.int32),
                "targets": sds((b, s), jnp.int32),
            }
            self._add_frontend_specs(batch, b, s, dtype)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": sds((b, s), jnp.int32)}
            self._add_frontend_specs(batch, b, s, dtype)
            return batch
        if shape.kind == "decode":
            return {
                "token": sds((b, 1), jnp.int32),
                "pos": sds((), jnp.int32),
            }
        raise ValueError(shape.kind)

    def _add_frontend_specs(self, batch: Dict, b: int, s: int, dtype) -> None:
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        if cfg.frontend == "audio":
            batch["enc_embeds"] = sds((b, cfg.encoder.n_frames, cfg.d_model), dtype)
        elif cfg.frontend == "vision":
            batch["vision_embeds"] = sds((b, s, cfg.d_model), dtype)
            batch["vision_mask"] = sds((b, s), jnp.bool_)
            batch["positions"] = sds((3, b, s), jnp.int32)


def build_model(cfg: ModelConfig, shape: Optional[InputShape] = None) -> Model:
    """Build a Model; enables the documented sliding-window variant when the
    workload is long_500k and the arch is full-attention (DESIGN.md §5)."""
    swa = None
    if shape is not None and shape.name == "long_500k" and cfg.long_context == "swa-variant":
        swa = cfg.swa_variant_window
    return Model(cfg=cfg, swa_override=swa)


# re-export for repro.models.__init__
init_params = tfm.init_params
init_cache = tfm.init_cache
