"""Attention mixers: GQA (sliding window, logit softcap, RoPE/M-RoPE),
MLA (multi-head latent attention), cross-attention, and their decode paths.

KV caches for sliding-window layers are ring buffers of capacity
``min(window, max_seq)`` — token ``t`` lives in slot ``t % C`` — so a
windowed layer at 500k context holds only ``window`` tokens of KV.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import runtime
from repro.models.common import apply_rope, dense_init, rmsnorm, softcap
from repro.sharding.rules import constrain

NEG_INF = -2.3819763e38  # same constant XLA uses for -inf masking in f32


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attn_params(cfg: ModelConfig, spec: LayerSpec, key, dtype) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (hq * hd, d), dtype),
    }
    if spec.cross_attn:
        p.update({
            "xwq": dense_init(ks[4], (d, hq * hd), dtype),
            "xwk": dense_init(ks[5], (d, hkv * hd), dtype),
            "xwv": dense_init(ks[6], (d, hkv * hd), dtype),
            "xwo": dense_init(ks[7], (hq * hd, d), dtype),
        })
    return p


def init_mla_params(cfg: ModelConfig, key, dtype) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "wuq": dense_init(ks[1], (m.q_lora_rank, h * (dn + dr)), dtype),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
        "wkr": dense_init(ks[3], (d, dr), dtype),
        "wukv": dense_init(ks[4], (m.kv_lora_rank, h * (dn + dv)), dtype),
        "wo": dense_init(ks[5], (h * dv, d), dtype),
    }


# ---------------------------------------------------------------------------
# Cache layout
# ---------------------------------------------------------------------------


def attn_cache_len(cfg: ModelConfig, spec: LayerSpec, max_seq: int,
                   swa_override: Optional[int] = None) -> int:
    window = spec.window
    if swa_override is not None and spec.mixer in ("attn",) and window is None:
        window = swa_override
    if window is None:
        return max_seq
    return min(window, max_seq)


def init_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int,
                    dtype, swa_override: Optional[int] = None,
                    enc_frames: Optional[int] = None) -> Dict:
    c = attn_cache_len(cfg, spec, max_seq, swa_override)
    if spec.mixer == "mla":
        m = cfg.mla
        cache = {
            "ckv": jnp.zeros((batch, c, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, c, m.qk_rope_head_dim), dtype),
        }
    else:
        cache = {
            "k": jnp.zeros((batch, c, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, c, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if spec.cross_attn:
        assert enc_frames is not None
        cache["xk"] = jnp.zeros((batch, enc_frames, cfg.n_kv_heads, cfg.head_dim), dtype)
        cache["xv"] = jnp.zeros((batch, enc_frames, cfg.n_kv_heads, cfg.head_dim), dtype)
    return cache


# ---------------------------------------------------------------------------
# Score computation (GQA aware)
# ---------------------------------------------------------------------------


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,S,Hq,D), k: (B,T,Hkv,D) -> scores (B,S,Hq,T) in f32."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, s, hkv, g, d)
    kf = k.astype(jnp.float32)
    sc = jnp.einsum("bskgd,btkd->bskgt", qf, kf)
    return sc.reshape(b, s, hq, k.shape[1])


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,S,Hq,T), v: (B,T,Hkv,Dv) -> (B,S,Hq,Dv)."""
    b, s, hq, t = probs.shape
    hkv = v.shape[2]
    g = hq // hkv
    pf = probs.reshape(b, s, hkv, g, t)
    out = jnp.einsum("bskgt,btkd->bskgd", pf, v.astype(jnp.float32))
    return out.reshape(b, s, hq, v.shape[-1])


def _masked_softmax(scores: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


def make_causal_mask(s: int, t: int, window: Optional[int],
                     offset: int = 0) -> jax.Array:
    """(1,S,1,T) mask: query i (global position offset+i) may see key j<=i
    within the window."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m[None, :, None, :]


# threshold above which the full-sequence XLA path switches to the
# scan-chunked formulation (transient scores bq×T instead of S×T)
CHUNKED_ATTN_THRESHOLD = 2048
CHUNK_Q = 512


def _chunked_causal_attention(q, k, v, scale, window, cap):
    """Query-chunked causal attention: lax.scan over q blocks keeps the
    score transient at (B, bq, Hq, T) — the pure-XLA analogue of the flash
    kernel, used for long sequences on the dry-run path."""
    b, s, hq, hd = q.shape
    bq = CHUNK_Q
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (s + pad) // bq
    qc = q.reshape(b, nb, bq, hq, hd).transpose(1, 0, 2, 3, 4)  # (nb,B,bq,H,hd)

    def body(_, xs):
        qb, ib = xs
        offset = ib * bq
        sc = _gqa_scores(qb, k) * scale               # (B,bq,Hq,T)
        sc = softcap(sc, cap)
        qi = offset + jnp.arange(bq)[:, None]
        kj = jnp.arange(k.shape[1])[None, :]
        m = kj <= qi
        if window is not None:
            m &= kj > qi - window
        sc = jnp.where(m[None, :, None, :], sc, NEG_INF)
        probs = jax.nn.softmax(sc, axis=-1)
        return None, _gqa_out(probs, v)

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(nb)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nb * bq, hq, -1)
    return out[:, :s]


# ---------------------------------------------------------------------------
# Full-sequence attention (training / prefill / encoder)
# ---------------------------------------------------------------------------


def attention_full(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    swa_override: Optional[int] = None,
) -> jax.Array:
    """Self-attention over a full sequence. Returns (B,S,D)."""
    if spec.mixer == "mla":
        return _mla_full(cfg, p, x, positions)
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    if cfg.rope_mode in ("rope", "mrope"):
        sections = cfg.mrope_sections if cfg.rope_mode == "mrope" else None
        q = apply_rope(q, positions, cfg.rope_theta, sections)
        k = apply_rope(k, positions, cfg.rope_theta, sections)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5

    window = spec.window
    if swa_override is not None and window is None:
        window = swa_override

    if runtime.attention_impl() == "pallas" and causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q, k, v, scale=scale, window=window,
            logit_cap=cfg.attn_logit_softcap, causal=True)
    elif causal and s > CHUNKED_ATTN_THRESHOLD:
        out = _chunked_causal_attention(q, k, v, scale, window,
                                        cfg.attn_logit_softcap)
    else:
        scores = _gqa_scores(q, k) * scale
        scores = softcap(scores, cfg.attn_logit_softcap)
        mask = make_causal_mask(s, s, window) if causal else None
        probs = _masked_softmax(scores, mask)
        out = _gqa_out(probs, v)
    out = out.astype(x.dtype).reshape(b, s, hq * hd)
    return out @ p["wo"]


def cross_attention_full(cfg: ModelConfig, p: Dict, x: jax.Array,
                         enc_out: jax.Array) -> jax.Array:
    """Decoder cross-attention over encoder output (B,T,D)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["xwq"]).reshape(b, s, hq, hd)
    k = (enc_out @ p["xwk"]).reshape(b, enc_out.shape[1], hkv, hd)
    v = (enc_out @ p["xwv"]).reshape(b, enc_out.shape[1], hkv, hd)
    scores = _gqa_scores(q, k) * hd ** -0.5
    probs = _masked_softmax(scores, None)
    out = _gqa_out(probs, v).astype(x.dtype).reshape(b, s, hq * hd)
    return out @ p["xwo"]


def cross_attention_kv(cfg: ModelConfig, p: Dict, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    b, t, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["xwk"]).reshape(b, t, hkv, hd)
    v = (enc_out @ p["xwv"]).reshape(b, t, hkv, hd)
    return k, v


def _mla_full(cfg: ModelConfig, p: Dict, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    qlat = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (qlat @ p["wuq"]).reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)  # (B,S,r)
    kr = apply_rope((x @ p["wkr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]  # (B,S,dr)
    kv = (ckv @ p["wukv"]).reshape(b, s, h, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    scale = (dn + dr) ** -0.5

    def block(qn_b, qr_b, offset, bq):
        sc = jnp.einsum("bshd,bthd->bsht", qn_b.astype(jnp.float32),
                        kn.astype(jnp.float32))
        sc += jnp.einsum("bshd,btd->bsht", qr_b.astype(jnp.float32),
                         kr.astype(jnp.float32))
        sc *= scale
        qi = offset + jnp.arange(bq)[:, None]
        kj = jnp.arange(s)[None, :]
        sc = jnp.where((kj <= qi)[None, :, None, :], sc, NEG_INF)
        probs = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bsht,bthd->bshd", probs, v.astype(jnp.float32))

    if s > CHUNKED_ATTN_THRESHOLD:
        bq = CHUNK_Q
        pad = (-s) % bq
        qn_p = jnp.pad(qn, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else qn
        qr_p = jnp.pad(qr, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else qr
        nb = (s + pad) // bq
        qn_c = qn_p.reshape(b, nb, bq, h, dn).transpose(1, 0, 2, 3, 4)
        qr_c = qr_p.reshape(b, nb, bq, h, dr).transpose(1, 0, 2, 3, 4)

        def body(_, xs):
            qn_b, qr_b, ib = xs
            return None, block(qn_b, qr_b, ib * bq, bq)

        _, out = jax.lax.scan(body, None, (qn_c, qr_c, jnp.arange(nb)))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, nb * bq, h, dv)[:, :s]
    else:
        out = block(qn, qr, 0, s)
    out = out.astype(x.dtype).reshape(b, s, h * dv)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# Prefill (full attention + cache write)
# ---------------------------------------------------------------------------


def attention_prefill(cfg, spec, p, x, positions, cache, *,
                      swa_override=None, enc_out=None):
    """Full causal attention; also fills the layer KV cache.

    Tokens t ∈ [0, S) are written to ring slot t % C.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    out = attention_full(cfg, spec, p, x, positions, causal=True,
                         swa_override=swa_override)
    new_cache = dict(cache)
    if spec.mixer == "mla":
        m = cfg.mla
        ckv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)
        kr = apply_rope((x @ p["wkr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
        new_cache["ckv"] = _ring_write_seq(cache["ckv"], ckv.astype(cache["ckv"].dtype))
        new_cache["krope"] = _ring_write_seq(cache["krope"], kr.astype(cache["krope"].dtype))
    else:
        hkv, hd = cfg.n_kv_heads, cfg.head_dim
        k = (x @ p["wk"]).reshape(b, s, hkv, hd)
        v = (x @ p["wv"]).reshape(b, s, hkv, hd)
        if cfg.rope_mode in ("rope", "mrope"):
            sections = cfg.mrope_sections if cfg.rope_mode == "mrope" else None
            k = apply_rope(k, positions, cfg.rope_theta, sections)
        new_cache["k"] = _ring_write_seq(cache["k"], k.astype(cache["k"].dtype))
        new_cache["v"] = _ring_write_seq(cache["v"], v.astype(cache["v"].dtype))
    if spec.cross_attn and enc_out is not None:
        xk, xv = cross_attention_kv(cfg, p, enc_out)
        new_cache["xk"] = xk.astype(cache["xk"].dtype)
        new_cache["xv"] = xv.astype(cache["xv"].dtype)
    return out, new_cache


def _ring_write_seq(buf: jax.Array, vals: jax.Array) -> jax.Array:
    """Write a full sequence (B,S,...) into a ring buffer (B,C,...):
    token t -> slot t % C. When S <= C this is a plain prefix write."""
    c = buf.shape[1]
    s = vals.shape[1]
    if s <= c:
        return jax.lax.dynamic_update_slice_in_dim(buf, vals, 0, axis=1)
    # keep the last C tokens, rotated so that token t sits at slot t % C
    tail = vals[:, s - c:]
    start = (s - c) % c
    rolled = jnp.roll(tail, shift=start, axis=1)
    return rolled


def _ring_write_at(buf: jax.Array, vals: jax.Array, offset: jax.Array,
                   valid_len: jax.Array) -> jax.Array:
    """Write a chunk (B,S,...) into a ring buffer (B,C,...) at an arbitrary
    start position: token ``offset + i`` -> slot ``(offset + i) % C``.

    Only the first ``valid_len`` tokens are real (the rest padding of a
    final partial chunk) — padded tokens are never written, so slots that
    still hold live earlier tokens of a windowed layer are not clobbered.
    When the valid region exceeds C only its last C tokens land (unique
    slots), matching ``_ring_write_seq``'s keep-the-tail semantics. Both
    ``offset`` and ``valid_len`` may be traced scalars: dropped writes are
    routed out of bounds (scatter ``mode="drop"``), so one compiled shape
    serves every (offset, valid_len)."""
    c = buf.shape[1]
    s = vals.shape[1]
    i = jnp.arange(s)
    keep = (i < valid_len) & (i >= valid_len - c)
    slots = jnp.where(keep, jnp.mod(offset + i, c), c)   # c = out of bounds
    return buf.at[:, slots].set(vals.astype(buf.dtype), mode="drop")


# ---------------------------------------------------------------------------
# Chunked prefill (chunk attends over [cache ++ chunk] at a position offset)
# ---------------------------------------------------------------------------


def attention_prefill_chunk(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,            # (B, S_chunk, D) — chunk at global offset
    offset: jax.Array,       # scalar int32: global position of chunk token 0
    positions: jax.Array,    # (B, S_chunk) or (3, B, S_chunk) rope positions
    valid_len: jax.Array,    # scalar int32: real tokens in the chunk (rest pad)
    cache: Dict,
    *,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, Dict]:
    """One prefill chunk against an existing cache: queries attend over
    ``[cache ++ chunk]`` with per-query causal (and sliding-window) masks at
    the correct position offset, then the chunk's K/V ring-write into the
    cache at slots ``(offset + i) % C``.

    The prior-cache segment is read *before* the write, so a windowed layer
    whose chunk wraps the ring never loses in-window history mid-chunk.
    Padded tail tokens (``i >= valid_len``) produce garbage rows that the
    caller discards and are neither attended (causality excludes them for
    every valid query) nor written. Everything is shape-static except the
    traced ``offset``/``valid_len`` scalars — one compiled executable per
    chunk shape."""
    if spec.mixer == "mla":
        return _mla_prefill_chunk(cfg, p, x, offset, positions, valid_len,
                                  cache)
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    c = cache["k"].shape[1]
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.rope_mode in ("rope", "mrope"):
        sections = cfg.mrope_sections if cfg.rope_mode == "mrope" else None
        q = apply_rope(q, positions, cfg.rope_theta, sections)
        k = apply_rope(k, positions, cfg.rope_theta, sections)
    window = spec.window
    if swa_override is not None and window is None:
        window = swa_override
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5

    # two segments, merged softmax: (a) the prior cache — before the chunk,
    # ring slot j holds token h_j = (offset-1) - ((offset-1-j) mod C), valid
    # while h_j >= 0 (and in-window per query); (b) the chunk itself, plain
    # causal at a shared offset (so the mask is offset-independent).
    qi = offset + jnp.arange(s)                          # global query pos
    j = jnp.arange(c)
    hj = (offset - 1) - jnp.mod(offset - 1 - j, c)       # cached token ids
    m_hist = jnp.broadcast_to((hj >= 0) & (offset > 0), (s, c))
    ii = jnp.arange(s)
    m_chunk = (ii[None, :] <= ii[:, None]) & (ii[None, :] < valid_len)
    if window is not None:
        m_hist = m_hist & (hj[None, :] > qi[:, None] - window)
        m_chunk = m_chunk & (ii[None, :] > ii[:, None] - window)
    sc_hist = _gqa_scores(q, cache["k"]) * scale         # (B,S,Hq,C)
    sc_chunk = _gqa_scores(q, k) * scale                 # (B,S,Hq,S)
    scores = jnp.concatenate([sc_hist, sc_chunk], axis=-1)
    scores = softcap(scores, cfg.attn_logit_softcap)
    mask = jnp.concatenate([m_hist, m_chunk], axis=-1)   # (S, C+S)
    probs = _masked_softmax(scores, mask[None, :, None, :])
    v_all = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
    out = _gqa_out(probs, v_all).astype(x.dtype).reshape(b, s, hq * hd)
    out = out @ p["wo"]

    new_cache = dict(cache)
    new_cache["k"] = _ring_write_at(cache["k"], k, offset, valid_len)
    new_cache["v"] = _ring_write_at(cache["v"], v, offset, valid_len)
    return out, new_cache


def _mla_prefill_chunk(cfg, p, x, offset, positions, valid_len, cache):
    """MLA chunk prefill: write the chunk's latent KV into the cache, then
    attend every chunk query over the whole updated cache (the decode path's
    expand-from-latent, generalized to S queries). Write-then-attend is
    exact here because MLA caches are full-length (no sliding window), so a
    chunk never overwrites history a query still needs."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    c = cache["ckv"].shape[1]
    qlat = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (qlat @ p["wuq"]).reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv_t = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)
    kr_t = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0]
    new_ckv = _ring_write_at(cache["ckv"], ckv_t, offset, valid_len)
    new_kr = _ring_write_at(cache["krope"], kr_t, offset, valid_len)
    kv = (new_ckv @ p["wukv"]).reshape(b, c, h, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    scale = (dn + dr) ** -0.5
    sc = jnp.einsum("bshd,bthd->bsht", qn.astype(jnp.float32),
                    kn.astype(jnp.float32))
    sc += jnp.einsum("bshd,btd->bsht", qr.astype(jnp.float32),
                     new_kr.astype(jnp.float32))
    sc *= scale
    # after the write, ring slot j holds token P - ((P - j) mod C) for the
    # last written position P; causal: visible iff 0 <= t_j <= query pos
    last = offset + valid_len - 1
    tj = last - jnp.mod(last - jnp.arange(c), c)
    qi = offset + jnp.arange(s)
    mask = (tj[None, :] >= 0) & (tj[None, :] <= qi[:, None])     # (S, C)
    sc = jnp.where(mask[None, :, None, :], sc, NEG_INF)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bsht,bthd->bshd", probs, v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, s, h * dv)
    out = out @ p["wo"]
    new_cache = dict(cache)
    new_cache["ckv"], new_cache["krope"] = new_ckv, new_kr
    return out, new_cache


# ---------------------------------------------------------------------------
# Decode (single token vs cache)
# ---------------------------------------------------------------------------


def attention_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict,
    x: jax.Array,           # (B, 1, D)
    pos: jax.Array,         # scalar int32 — or (B,) per-row write positions
    positions: jax.Array,   # (B, 1) or (3, B, 1) rope positions of this token
    cache: Dict,
    *,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, Dict]:
    if spec.mixer == "mla":
        return _mla_decode(cfg, p, x, pos, positions, cache)
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    c = cache["k"].shape[1]
    q = (x @ p["wq"]).reshape(b, 1, hq, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if cfg.rope_mode in ("rope", "mrope"):
        sections = cfg.mrope_sections if cfg.rope_mode == "mrope" else None
        q = apply_rope(q, positions, cfg.rope_theta, sections)
        k = apply_rope(k, positions, cfg.rope_theta, sections)
    new_k = _ring_write_token(cache["k"], k, pos)
    new_v = _ring_write_token(cache["v"], v, pos)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5
    scores = _gqa_scores(q, new_k) * scale       # (B,1,Hq,C)
    scores = softcap(scores, cfg.attn_logit_softcap)
    valid = _ring_valid_mask(pos, c)             # (C,) or (B,C)
    scores = _apply_valid_mask(scores, valid)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, new_v).astype(x.dtype).reshape(b, 1, hq * hd)
    out = out @ p["wo"]
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_k, new_v
    return out, new_cache


def cross_attention_decode(cfg: ModelConfig, p: Dict, x: jax.Array, cache: Dict) -> jax.Array:
    b, _, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["xwq"]).reshape(b, 1, hq, hd)
    scores = _gqa_scores(q, cache["xk"]) * hd ** -0.5
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, cache["xv"]).astype(x.dtype).reshape(b, 1, hq * hd)
    return out @ p["xwo"]


def _ring_valid_mask(pos: jax.Array, c: int) -> jax.Array:
    """Which ring slots hold live tokens once token ``pos`` is written.

    Slot j holds token t_j = pos - ((pos - j) mod C); valid iff t_j >= 0.
    For a full (non-ring) cache this reduces to j <= pos. ``pos`` may be a
    scalar (uniform batch) → (C,), or per-row (B,) → (B, C).
    """
    j = jnp.arange(c)
    p = pos[..., None]              # () -> (1,), (B,) -> (B, 1)
    t = p - jnp.mod(p - j, c)
    return t >= 0


def _apply_valid_mask(scores: jax.Array, valid: jax.Array) -> jax.Array:
    """Mask decode scores (B,1,H,C) with a (C,) or per-row (B,C) mask."""
    if valid.ndim == 1:
        valid = valid[None, None, None, :]
    else:
        valid = valid[:, None, None, :]
    return jnp.where(valid, scores, NEG_INF)


def _ring_write_token(buf: jax.Array, vals: jax.Array, pos: jax.Array) -> jax.Array:
    """Write one token's entries (B,1,...) into the ring buffer (B,C,...).

    Scalar ``pos`` writes every row at the same slot (uniform batch); a
    (B,) ``pos`` writes row i at its own slot ``pos[i] % C`` — the
    continuous-batching case where requests sit at different positions.
    """
    c = buf.shape[1]
    vals = vals.astype(buf.dtype)
    if jnp.ndim(pos) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, vals, jnp.mod(pos, c),
                                                   axis=1)
    b = buf.shape[0]
    return buf.at[jnp.arange(b), jnp.mod(pos, c)].set(vals[:, 0])


def _mla_decode(cfg, p, x, pos, positions, cache):
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    c = cache["ckv"].shape[1]
    qlat = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (qlat @ p["wuq"]).reshape(b, 1, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    ckv_t = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)
    kr_t = apply_rope((x @ p["wkr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    new_ckv = _ring_write_token(cache["ckv"], ckv_t, pos)
    new_kr = _ring_write_token(cache["krope"], kr_t, pos)
    kv = (new_ckv @ p["wukv"]).reshape(b, c, h, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    scale = (dn + dr) ** -0.5
    sc = jnp.einsum("bshd,bthd->bsht", qn.astype(jnp.float32), kn.astype(jnp.float32))
    sc += jnp.einsum("bshd,btd->bsht", qr.astype(jnp.float32), new_kr.astype(jnp.float32))
    sc *= scale
    valid = _ring_valid_mask(pos, c)
    sc = _apply_valid_mask(sc, valid)
    probs = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bsht,bthd->bshd", probs, v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, 1, h * dv)
    out = out @ p["wo"]
    new_cache = dict(cache)
    new_cache["ckv"], new_cache["krope"] = new_ckv, new_kr
    return out, new_cache
