"""Feed-forward blocks: SwiGLU and GELU MLPs."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.sharding.rules import constrain


def init_swiglu_params(cfg: ModelConfig, key, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), dtype),
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype),
    }


def init_gelu_params(cfg: ModelConfig, key, dtype) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_in": dense_init(k1, (d, f), dtype),
        "w_out": dense_init(k2, (f, d), dtype),
    }


def swiglu(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ p["w_down"]


def gelu_mlp(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ p["w_in"], approximate=True)
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ p["w_out"]
