"""Top-k token-choice Mixture-of-Experts with sort-based capacity dispatch.

GShard-style one-hot dispatch materializes an (N, E, C) tensor — infeasible
at our batch sizes. We instead sort (token, expert) assignments by expert id
and scatter into a dense (E, C, d) buffer, run batched expert matmuls, and
scatter back. Tokens beyond an expert's capacity are dropped (their combine
weight contribution is zero), matching capacity-factor MoE semantics
[arXiv:2401.04088, Switch Transformers].

Load-balancing auxiliary loss: E * sum_e(fraction_e * router_prob_e).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.sharding.rules import constrain


def init_moe_params(cfg: ModelConfig, key, dtype) -> Dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, e), jnp.float32),
        "w_gate": dense_init(k2, (e, d, f), dtype, in_axis=1),
        "w_up": dense_init(k3, (e, d, f), dtype, in_axis=1),
        "w_down": dense_init(k4, (e, f, d), dtype, in_axis=1),
    }


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    # pad to an MXU-friendly multiple
    return max(8, -(-cap // 8) * 8)


def moe_ffn(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Under an active device mesh the dispatch runs inside ``shard_map`` over
    the data axes — sorting and capacity are *per data shard* (a global
    argsort would force GSPMD to replicate the full token buffer), and the
    tensor-parallel expert matmuls psum their partial products over the
    model axes. Without a mesh (unit tests) it runs as plain XLA."""
    from repro.sharding import rules as R
    mesh = R.current_mesh()
    rules = R.current_rules()
    if mesh is not None and rules is not None:
        return _moe_ffn_sharded(cfg, p, x, mesh, rules)
    return _moe_ffn_local(cfg, p, x)


def _moe_ffn_sharded(cfg: ModelConfig, p: Dict, x: jax.Array, mesh, rules):
    from jax.sharding import PartitionSpec as P
    try:
        shard_map = jax.shard_map
    except AttributeError:  # older JAX
        from jax.experimental.shard_map import shard_map as _sm
        shard_map = _sm

    dp = rules.get("batch") or ()
    dp = (dp,) if isinstance(dp, str) else tuple(dp)
    tp = rules.get("expert_mlp") or ()
    tp = (tp,) if isinstance(tp, str) else tuple(tp)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in dp if axis_sizes.get(a, 1) > 1 and x.shape[0] % axis_sizes[a] == 0)
    f = cfg.moe.d_ff_expert
    tp = tuple(a for a in tp if axis_sizes.get(a, 1) > 1 and f % axis_sizes[a] == 0)

    def local(xb, router, wg, wu, wd):
        pl = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        out, aux = _moe_ffn_local(cfg, pl, xb, psum_axes=tp, manual=True)
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return out, aux

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp or None), P(), P(None, None, tp or None),
                  P(None, None, tp or None), P(None, tp or None, None)),
        out_specs=(P(dp or None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_ffn_local(cfg: ModelConfig, p: Dict, x: jax.Array,
                   psum_axes: Tuple[str, ...] = (),
                   manual: bool = False) -> Tuple[jax.Array, jax.Array]:
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.n_experts, m.top_k
    cap = expert_capacity(n, cfg)
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (N, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)    # renormalize

    # auxiliary load-balance loss
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0)) * m.router_aux_weight

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = top_e.reshape(n * k)                             # (NK,)
    flat_w = top_w.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)                   # token id per assignment
    order = jnp.argsort(flat_e, stable=True)                  # group by expert
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]
    # rank within expert group = index - first index of that expert
    idx = jnp.arange(n * k)
    # first occurrence index per expert via cumulative counts
    counts = jnp.bincount(se, length=e)                       # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = idx - starts[se]
    keep = rank < cap
    dest = jnp.where(keep, se * cap + rank, e * cap)          # sentinel row e*cap

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[dest].set(xf[st], mode="drop")
    ein = buf[: e * cap].reshape(e, cap, d)
    if not manual:  # sharding constraints are illegal under manual axes
        ein = constrain(ein, (None, None, "embed_act"))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", ein, p["w_up"])
    if not manual:
        h = constrain(h, ("experts", None, "expert_mlp"))
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # (E, C, D)

    eflat = jnp.concatenate(
        [eout.reshape(e * cap, d), jnp.zeros((1, d), eout.dtype)], axis=0)
    gathered = eflat[dest] * sw[:, None].astype(eout.dtype)   # (NK, D)
    out = jnp.zeros((n, d), x.dtype).at[st].add(gathered.astype(x.dtype))
    if psum_axes:
        # tensor-parallel experts: each shard computed f/|tp| of the hidden
        # dim, so the combined output is a partial sum — reduce it (combine
        # is linear, so psum after the scatter touches n·d, not E·C·d)
        out = jax.lax.psum(out, psum_axes)
    return out.reshape(b, s, d), aux
