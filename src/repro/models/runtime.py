"""Per-process model-runtime knobs (attention backend selection, remat)."""

from __future__ import annotations

import contextlib
import contextvars

# "xla"  — pure-jnp attention/SSD (reference path; used for dry-run lowering)
# "pallas" — Pallas TPU kernels (interpret=True on CPU) for the hot paths
_attn_impl = contextvars.ContextVar("repro_attn_impl", default="xla")


def attention_impl() -> str:
    return _attn_impl.get()


@contextlib.contextmanager
def use_attention_impl(name: str):
    assert name in ("xla", "pallas"), name
    tok = _attn_impl.set(name)
    try:
        yield
    finally:
        _attn_impl.reset(tok)
