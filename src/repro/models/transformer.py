"""Segment-scan transformer driver.

Parameters for each repeated layer pattern are stacked along a leading
``repeats`` dimension and the pattern is applied under ``jax.lax.scan`` —
one pattern body is traced/compiled regardless of depth, which keeps the
HLO small enough to compile 80-layer production configs with 512 host
devices on the dry-run machine. KV/SSM caches share the same stacked
layout so decode scans carry them as scan xs/ys.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, Segment
from repro.models import blocks
from repro.models.common import apply_norm, embed_init, norm_params, softcap
from repro.sharding.rules import constrain


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_pattern_params(cfg: ModelConfig, pattern, key, dtype) -> Dict:
    ks = jax.random.split(key, len(pattern))
    return {f"p{i}": blocks.init_layer_params(cfg, spec, ks[i], dtype)
            for i, spec in enumerate(pattern)}


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    n_seg = len(cfg.segments)
    keys = jax.random.split(key, n_seg + 4)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": norm_params(cfg, keys[1]),
        "segments": [],
    }
    for si, seg in enumerate(cfg.segments):
        seg_keys = jax.random.split(keys[2 + si], seg.repeats)
        stacked = jax.vmap(
            lambda k: _init_pattern_params(cfg, seg.pattern, k, dtype)
        )(seg_keys)
        params["segments"].append(stacked)
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(keys[-2], (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.encoder is not None:
        enc_spec = LayerSpec(mixer="attn", ffn="gelu")
        enc_keys = jax.random.split(keys[-1], cfg.encoder.n_layers)
        enc_layers = jax.vmap(
            lambda k: blocks.init_layer_params(cfg, enc_spec, k, dtype)
        )(enc_keys)
        params["encoder"] = {
            "layers": enc_layers,
            "final_norm": norm_params(cfg, keys[-1]),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.float32,
               swa_override: Optional[int] = None) -> Dict:
    """Stacked per-segment caches mirroring the parameter layout."""
    enc_frames = cfg.encoder.n_frames if cfg.encoder is not None else None
    cache: Dict[str, Any] = {"segments": []}
    for seg in cfg.segments:
        one = {
            f"p{i}": blocks.init_layer_cache(
                cfg, spec, batch, max_seq, dtype,
                swa_override=swa_override, enc_frames=enc_frames)
            for i, spec in enumerate(seg.pattern)
        }
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (seg.repeats,) + x.shape), one)
        cache["segments"].append(stacked)
    return cache


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal position encodings, shape positions.shape + (d,)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_tokens(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 positions: jax.Array,
                 vision_embeds: Optional[jax.Array] = None,
                 vision_mask: Optional[jax.Array] = None) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if vision_embeds is not None and vision_mask is not None:
        # scatter precomputed patch embeddings (frontend stub) over the
        # positions flagged by vision_mask; vision_embeds is (B, S, D) aligned
        x = jnp.where(vision_mask[..., None], vision_embeds.astype(x.dtype), x)
    if cfg.rope_mode == "learned":
        # implemented as sinusoidal (parameter-free — covers arbitrary decode
        # lengths; documented deviation from whisper's learned table)
        pos2d = positions if positions.ndim == 2 else positions[0]
        x = x + _sinusoid(pos2d, cfg.d_model).astype(x.dtype)
    x = constrain(x, ("batch", "seq_act", "embed_act"))
    return x


def final_logits(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # padded-vocab sharding: masked pad columns never win softmax/argmax
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def encode(cfg: ModelConfig, params: Dict, enc_embeds: jax.Array) -> jax.Array:
    """enc_embeds: (B, frames, D) precomputed frontend-stub embeddings."""
    enc_spec = LayerSpec(mixer="attn", ffn="gelu")
    b, t, _ = enc_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    x = enc_embeds + _sinusoid(pos, cfg.d_model).astype(enc_embeds.dtype)

    def body(h, layer_p):
        h, _ = blocks.apply_layer(cfg, enc_spec, layer_p, h, pos, causal=False)
        return h, None

    # rematerialize encoder internals in the backward pass — without this the
    # scan saves every layer's full (frames × frames) attention scores
    body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return apply_norm(cfg, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
    vision_embeds: Optional[jax.Array] = None,
    vision_mask: Optional[jax.Array] = None,
    swa_override: Optional[int] = None,
    remat_policy=None,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        if cfg.rope_mode == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    enc_out = None
    if cfg.encoder is not None:
        assert enc_embeds is not None, "whisper needs encoder frontend embeddings"
        enc_out = encode(cfg, params, enc_embeds)
    x = embed_tokens(cfg, params, tokens, positions, vision_embeds, vision_mask)
    aux_total = jnp.zeros((), jnp.float32)

    for seg, seg_params in zip(cfg.segments, params["segments"]):
        def pattern_body(h, layer_params, seg=seg):
            aux_sum = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(seg.pattern):
                h, aux = blocks.apply_layer(
                    cfg, spec, layer_params[f"p{i}"], h, positions,
                    enc_out=enc_out, swa_override=swa_override)
                aux_sum = aux_sum + aux
            return h, aux_sum

        if remat_policy is not None:
            pattern_body = jax.checkpoint(pattern_body, policy=remat_policy,
                                          static_argnums=())

        def scan_body(carry, layer_params):
            h, aux_acc = carry
            h, aux_sum = pattern_body(h, layer_params)
            return (h, aux_acc + aux_sum), None

        (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total), seg_params)

    logits = final_logits(cfg, params, x)
    return logits, aux_total


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    cache: Dict,
    *,
    positions: Optional[jax.Array] = None,
    enc_embeds: Optional[jax.Array] = None,
    vision_embeds: Optional[jax.Array] = None,
    vision_mask: Optional[jax.Array] = None,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, Dict]:
    """Forward over the prompt; returns (last-token logits, filled cache)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        if cfg.rope_mode == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    enc_out = None
    if cfg.encoder is not None:
        assert enc_embeds is not None
        enc_out = encode(cfg, params, enc_embeds)
    x = embed_tokens(cfg, params, tokens, positions, vision_embeds, vision_mask)

    new_cache: Dict[str, Any] = {"segments": []}
    for seg, seg_params, seg_cache in zip(
            cfg.segments, params["segments"], cache["segments"]):

        def scan_body(h, xs, seg=seg):
            layer_params, layer_cache = xs
            out_cache = {}
            for i, spec in enumerate(seg.pattern):
                h, _, c = blocks.apply_layer_prefill(
                    cfg, spec, layer_params[f"p{i}"], h, positions,
                    layer_cache[f"p{i}"], enc_out=enc_out,
                    swa_override=swa_override)
                out_cache[f"p{i}"] = c
            return h, out_cache

        x, seg_new_cache = jax.lax.scan(scan_body, x, (seg_params, seg_cache))
        new_cache["segments"].append(seg_new_cache)

    logits = final_logits(cfg, params, x[:, -1:, :])
    return logits, new_cache


# ---------------------------------------------------------------------------
# Chunked prefill
# ---------------------------------------------------------------------------


def prefill_chunk(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,   # (B, S_chunk) int32 — chunk at global offset
    offset: jax.Array,   # scalar int32: global position of chunk token 0
    valid_len: jax.Array,  # scalar int32: real tokens (the rest is padding)
    cache: Dict,
    *,
    swa_override: Optional[int] = None,
) -> Tuple[jax.Array, Dict]:
    """Cache-aware prefill of one prompt chunk (the serving scheduler's
    chunked-prefill entry point). Each chunk attends over
    ``[cache ++ chunk]`` at its global position offset, so prefilling a
    prompt ``chunk`` tokens at a time produces the same cache a whole-prompt
    ``prefill`` would. Returns (logits of the last *valid* chunk token
    (B,1,V), updated cache). Shapes are static except the traced
    ``offset``/``valid_len`` scalars — mixed prompt lengths share ONE
    compiled executable per chunk shape."""
    b, s = tokens.shape
    positions = offset + jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, b, s))
    x = embed_tokens(cfg, params, tokens, positions)

    new_cache: Dict[str, Any] = {"segments": []}
    for seg, seg_params, seg_cache in zip(
            cfg.segments, params["segments"], cache["segments"]):

        def scan_body(h, xs, seg=seg):
            layer_params, layer_cache = xs
            out_cache = {}
            for i, spec in enumerate(seg.pattern):
                h, _, c = blocks.apply_layer_prefill_chunk(
                    cfg, spec, layer_params[f"p{i}"], h, offset, positions,
                    valid_len, layer_cache[f"p{i}"],
                    swa_override=swa_override)
                out_cache[f"p{i}"] = c
            return h, out_cache

        x, seg_new_cache = jax.lax.scan(scan_body, x, (seg_params, seg_cache))
        new_cache["segments"].append(seg_new_cache)

    last = jax.lax.dynamic_slice_in_dim(x, valid_len - 1, 1, axis=1)
    logits = final_logits(cfg, params, last)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    cache: Dict,
    token: jax.Array,   # (B, 1) int32
    pos: jax.Array,     # scalar int32 — or (B,) per-row indices being written
    *,
    swa_override: Optional[int] = None,
    inplace: bool = True,
) -> Tuple[jax.Array, Dict]:
    """One autoregressive step. Returns (logits (B,1,V), new cache).

    ``pos`` may be a scalar (uniform batch — every row writes the same
    index) or a (B,) vector (continuous batching — each row sits at its own
    sequence position; rows are independent, so per-row results equal the
    corresponding single-request decode).

    ``inplace=True`` (default) threads the stacked cache through the layer
    scan as a CARRY updated with dynamic slice writes — the while-loop state
    aliases across iterations, so decode scratch is ~a single layer's
    working set. ``inplace=False`` is the naive xs→ys scan, which
    double-buffers the whole cache (≈2.6× cache in scratch) and exists as
    the recorded §Perf hillclimb-C baseline."""
    b = token.shape[0]
    if jnp.ndim(pos) == 0:
        positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    else:
        positions = pos.astype(jnp.int32)[:, None]
    if cfg.rope_mode == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, b, 1))
    x = embed_tokens(cfg, params, token, positions)

    new_cache: Dict[str, Any] = {"segments": []}
    for seg, seg_params, seg_cache in zip(
            cfg.segments, params["segments"], cache["segments"]):

        if inplace:
            # cache as scan CARRY with dynamic in-place slice updates: the
            # while-loop state aliases across iterations, so the stacked KV
            # buffer is updated in place instead of double-buffered as ys
            def carry_body(carry, xs, seg=seg):
                h, cache_st = carry
                layer_params, r = xs
                layer_cache = jax.tree.map(
                    lambda v: jax.lax.dynamic_index_in_dim(v, r, 0, keepdims=False),
                    cache_st)
                for i, spec in enumerate(seg.pattern):
                    h, c = blocks.apply_layer_decode(
                        cfg, spec, layer_params[f"p{i}"], h, pos, positions,
                        layer_cache[f"p{i}"], swa_override=swa_override)
                    layer_cache[f"p{i}"] = c
                cache_st = jax.tree.map(
                    lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                        buf, v.astype(buf.dtype), r, 0),
                    cache_st, layer_cache)
                return (h, cache_st), None

            (x, seg_cache), _ = jax.lax.scan(
                carry_body, (x, seg_cache),
                (seg_params, jnp.arange(seg.repeats)))
            new_cache["segments"].append(seg_cache)
            continue

        def scan_body(h, xs, seg=seg):
            layer_params, layer_cache = xs
            out_cache = {}
            for i, spec in enumerate(seg.pattern):
                h, c = blocks.apply_layer_decode(
                    cfg, spec, layer_params[f"p{i}"], h, pos, positions,
                    layer_cache[f"p{i}"], swa_override=swa_override)
                out_cache[f"p{i}"] = c
            return h, out_cache

        x, seg_new_cache = jax.lax.scan(scan_body, x, (seg_params, seg_cache))
        new_cache["segments"].append(seg_new_cache)

    logits = final_logits(cfg, params, x)
    return logits, new_cache
