"""Request lifecycle for the continuous-batching scheduler.

A ``Request`` is what a client submits: prompt tokens, a decode budget,
sampling parameters, and (optionally) an ``SLOSpec`` — priority class and
TTFT/TPOT deadlines the SLO-aware scheduler acts on. ``RequestState`` is
the scheduler's view of it moving through QUEUED → PREFILL → DECODE →
DONE:

- QUEUED   — waiting in the arrival queue (not yet admitted: no slot, no
             capacity reservation);
- PREFILL  — admitted: prompt being prefilled into its batch slot. With
             chunked prefill (``SchedulerConfig.chunk_size``) this state
             persists across scheduler steps — ``prefill_pos`` tracks how
             many prompt tokens have landed, and the partial batch-1 row
             cache lives on ``chunk_cache`` between steps (resident mode)
             or parked page-by-page in the memory pool (kv_offload mode);
- DECODE   — joined the running batch; one token per scheduler step;
- DONE     — produced ``max_new_tokens``; slot freed, reservation released,
             pages dropped.

Two SLO-mode-only states branch off that spine:

- PREEMPTED — was PREFILL or DECODE; its slot was handed to a deadline-
              pressed higher-priority arrival. The KV rows live on
              ``chunk_cache`` (resident) or stay parked in the pool
              (kv_offload); the capacity reservation is *kept* (the pages
              really occupy pool space), so restoring never re-admits.
              Resumes to its prior state when a slot frees — token stream
              byte-identical to an unpreempted run;
- SHED      — dropped from the queue before admission because its TTFT
              deadline was already unmeetable (goodput: no prefill spent
              on certainly-missed work). Terminal, like DONE, but with no
              output.

Each admitted request owns a ``KVPageTable`` (offload.kvcache): its slice
of the stacked decode cache, page-granular, living in the memory pool when
the scheduler runs with ``kv_offload=True``. Sampling reproduces
``ServeEngine.generate`` per request exactly: the same seed-derived key
stream, first token from the prefill logits, one split per decode step —
so at ``temperature=0`` (and for any temperature, against a batch-1
engine run with the same seed) continuous batching is token-identical to
serving each request alone.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional

import jax
import numpy as np

from repro.offload.kvcache import KVPageTable
from repro.slo.policy import SLOSpec

QUEUED = "QUEUED"
PREFILL = "PREFILL"
DECODE = "DECODE"
DONE = "DONE"
PREEMPTED = "PREEMPTED"
SHED = "SHED"

_REQUEST_IDS = itertools.count()


@dataclasses.dataclass
class Request:
    """One client request: prompt ids (1-D), decode budget, sampling."""

    tokens: np.ndarray                 # (S,) int32 prompt ids
    max_new_tokens: int
    arrival: float = 0.0               # scheduler-clock arrival time
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: int = 0
    slo: Optional[SLOSpec] = None      # None → standard class, no deadlines
    req_id: int = dataclasses.field(default_factory=lambda: next(_REQUEST_IDS))

    def __post_init__(self) -> None:
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def total_len(self) -> int:
        """Worst-case sequence length (prompt + all generated tokens)."""
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class RequestState:
    """Scheduler-side mutable state of one request."""

    request: Request
    status: str = QUEUED
    slot: Optional[int] = None         # batch row while admitted
    pos: int = 0                       # next cache write index for decode
    prefill_pos: int = 0               # prompt tokens prefilled so far (chunked)
    chunk_cache: Optional[Any] = None  # partial row cache between chunk steps
    last_tok: int = -1                 # token fed to the next decode step
    out: List[int] = dataclasses.field(default_factory=list)
    key: Optional[jax.Array] = None    # per-request sampling key stream
    pages: Optional[KVPageTable] = None
    prefix_hit: Optional[Any] = None   # PrefixHit while admitted (refs held)
    reserve_key: str = ""              # pool reservation handle
    preemptions: int = 0               # times parked mid-flight (SLO mode)
    last_step: int = -1                # last scheduler step that decoded us
    joined_step: int = -1
    t_joined: Optional[float] = None   # admission time (queue-wait metric)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def req_id(self) -> int:
        return self.request.req_id

    @property
    def done(self) -> bool:
        return len(self.out) >= self.request.max_new_tokens

    def sample_key(self) -> jax.Array:
        """Next sampling key, mirroring ``ServeEngine.generate``: the raw
        seed key samples the first (prefill) token; every decode step
        splits once and samples with the subkey."""
        if self.key is None:
            self.key = jax.random.key(self.request.seed)
            return self.key
        self.key, sub = jax.random.split(self.key)
        return sub

    def tokens_array(self) -> np.ndarray:
        return np.asarray(self.out, np.int32)
