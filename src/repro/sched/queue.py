"""Arrival queue and pool-capacity-aware admission control.

Admission follows the SLO-offloading systems the ISSUE cites (Select-N,
Harvest): a request joins the running batch only if the pool's **device
tier + host tier** can hold its worst-case KV pages *on top of* current
occupancy and every already-admitted request's standing reservation
(``MemoryPoolManager.reserve``). Otherwise it stays QUEUED — the scheduler
never over-commits, so page parks can always be honored without touching
the (slow) remote tier.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.pool import DEVICE_TIER, HOST_TIER
from repro.pool.manager import MemoryPoolManager
from repro.sched.requests import Request, RequestState

ADMISSION_TIERS = (DEVICE_TIER, HOST_TIER)


class ArrivalQueue:
    """Pending requests ordered by (arrival time, request id) — FIFO among
    same-time arrivals regardless of submission order, so a future-dated
    head never shadows an already-arrived later submission."""

    def __init__(self, requests: Sequence[Request] = ()) -> None:
        self._q: List[RequestState] = []
        for r in requests:
            self.push(r)

    def push(self, request: Request) -> RequestState:
        state = RequestState(request=request)
        self._q.append(state)
        self._q.sort(key=lambda s: (s.request.arrival, s.req_id))
        return state

    def __len__(self) -> int:
        return len(self._q)

    def head_ready(self, now: float) -> Optional[RequestState]:
        """The next request whose arrival time has passed (FIFO), without
        removing it."""
        if self._q and self._q[0].request.arrival <= now:
            return self._q[0]
        return None

    def pop(self) -> RequestState:
        return self._q.pop(0)

    def next_arrival(self) -> Optional[float]:
        return self._q[0].request.arrival if self._q else None


class AdmissionController:
    """Reserves worst-case page capacity in the pool per admitted request;
    releases it at retirement. ``blocked`` counts admission refusals (the
    benchmark's queueing-pressure signal)."""

    def __init__(self, pool: MemoryPoolManager,
                 tiers: Sequence[str] = ADMISSION_TIERS) -> None:
        self.pool = pool
        self.tiers = tuple(tiers)
        self.blocked = 0

    def try_admit(self, state: RequestState, nbytes: int,
                  covers: Optional[str] = None) -> bool:
        """``covers``: the request's page-key prefix — its parked pages are
        charged via the reservation, not double-counted as occupancy."""
        key = f"admit/req{state.req_id}"
        if self.pool.reserve(key, nbytes, self.tiers, covers=covers):
            state.reserve_key = key
            return True
        self.blocked += 1
        return False

    def release(self, state: RequestState) -> None:
        if state.reserve_key:
            self.pool.release(state.reserve_key)
            state.reserve_key = ""

    def can_ever_admit(self, nbytes: int) -> bool:
        """Would the request fit in an *empty* pool — i.e. within the
        tiers' raw capacities? (deadlock guard)"""
        cap = 0
        for t in self.tiers:
            tier_cap = self.pool.occupancy(t)[1]
            if tier_cap is None:
                return True
            cap += tier_cap
        return nbytes <= cap


def poisson_trace(n_requests: int, *, rate: float, vocab_size: int,
                  prompt_lens: Sequence[int] = (4, 24),
                  new_tokens: Sequence[int] = (2, 16),
                  prompt_quantum: int = 1,
                  seed: int = 0) -> List[Request]:
    """Deterministic mixed-length Poisson arrival trace (benchmarks/tests):
    exponential inter-arrival gaps at ``rate`` requests per unit of
    scheduler time, uniform prompt/decode lengths in the given ranges.
    ``prompt_quantum`` rounds prompt lengths down to bucket multiples —
    bucketed serving keeps the set of prefill shapes (→ compiled
    executables) small."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Request] = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        s = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        s = max(prompt_lens[0], (s // prompt_quantum) * prompt_quantum)
        m = int(rng.integers(new_tokens[0], new_tokens[1] + 1))
        toks = rng.integers(0, vocab_size, size=s, dtype=np.int32)
        out.append(Request(tokens=toks, max_new_tokens=m, arrival=t, seed=i))
    return out
