"""Arrival queue and pool-capacity-aware admission control.

Admission follows the SLO-offloading systems the ISSUE cites (Select-N,
Harvest): a request joins the running batch only if the pool's
**admitting tiers** (declared per-``TierSpec`` in the topology; device +
host in the default chain) can hold its worst-case KV pages *on top of*
current occupancy and every already-admitted request's standing
reservation (``MemoryPoolManager.reserve``). Otherwise it stays QUEUED —
the scheduler never over-commits, so page parks can always be honored
without touching the slow non-admitting tiers.
"""

from __future__ import annotations

import bisect
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.pool import DEVICE_TIER, HOST_TIER
from repro.pool.manager import MemoryPoolManager
from repro.sched.requests import Request, RequestState
from repro.slo.policy import SLOSpec

#: the default chain's admitting tiers — kept for callers that pin the
#: historical pair explicitly; ``AdmissionController`` now defaults to the
#: pool topology's own ``admit`` declarations
ADMISSION_TIERS = (DEVICE_TIER, HOST_TIER)


class ArrivalQueue:
    """Pending requests ordered by (arrival time, request id) — FIFO among
    same-time arrivals regardless of submission order, so a future-dated
    head never shadows an already-arrived later submission."""

    def __init__(self, requests: Sequence[Request] = ()) -> None:
        self._q: List[RequestState] = []
        for r in requests:
            self.push(r)

    def push(self, request: Request) -> RequestState:
        """O(log n) search + O(n) insert (``bisect.insort``) instead of
        re-sorting the whole queue per submit — submitting a trace of n
        requests is O(n^2) worst case, not O(n^2 log n) with a full sort's
        constant factors on every push."""
        state = RequestState(request=request)
        bisect.insort(self._q, state,
                      key=lambda s: (s.request.arrival, s.req_id))
        return state

    def __len__(self) -> int:
        return len(self._q)

    def pending(self) -> Tuple[RequestState, ...]:
        """Snapshot of the queued states in arrival order — the public
        read the scheduler's progress bound uses (callers must not reach
        into the private list)."""
        return tuple(self._q)

    def head_ready(self, now: float) -> Optional[RequestState]:
        """The next request whose arrival time has passed (FIFO), without
        removing it."""
        if self._q and self._q[0].request.arrival <= now:
            return self._q[0]
        return None

    def ready(self, now: float) -> Tuple[RequestState, ...]:
        """Every request whose arrival time has passed, in arrival order —
        the SLO-aware scheduler re-ranks these by priority/deadline
        instead of taking the FIFO head."""
        i = bisect.bisect_right(self._q, now,
                                key=lambda s: s.request.arrival)
        return tuple(self._q[:i])

    def pop(self) -> RequestState:
        return self._q.pop(0)

    def remove(self, state: RequestState) -> None:
        """Remove a specific queued state (SLO admission takes the best
        candidate, not necessarily the head; shedding drops mid-queue).
        Matched by identity — dataclass equality would compare token
        arrays elementwise."""
        for i, s in enumerate(self._q):
            if s is state:
                del self._q[i]
                return
        raise ValueError(f"req {state.req_id} not queued")

    def next_arrival(self) -> Optional[float]:
        return self._q[0].request.arrival if self._q else None


class AdmissionController:
    """Reserves worst-case page capacity in the pool per admitted request;
    releases it at retirement. ``blocked`` counts admission refusals (the
    benchmark's queueing-pressure signal)."""

    def __init__(self, pool: MemoryPoolManager,
                 tiers: Optional[Sequence[str]] = None,
                 itemsize: Optional[int] = None) -> None:
        self.pool = pool
        self.tiers = (tuple(tiers) if tiers is not None
                      else pool.admission_tiers)
        # decoded element size of the pages this controller reserves for.
        # With a KV codec active, reservations stay in full-precision bytes
        # but codec tiers are counted at decoded-equivalent capacity — an
        # int8 tier holds 4× the fp32 pages its raw byte budget suggests.
        # Charging raw bytes there (the old behavior) double-charged
        # compressed pages and silently halved/quartered admission.
        self.itemsize = itemsize
        self.blocked = 0

    def try_admit(self, state: RequestState, nbytes: int,
                  covers: Optional[str] = None) -> bool:
        """``covers``: the request's page-key prefix — its parked pages are
        charged via the reservation, not double-counted as occupancy."""
        key = f"admit/req{state.req_id}"
        if self.pool.reserve(key, nbytes, self.tiers, covers=covers,
                             itemsize=self.itemsize):
            state.reserve_key = key
            return True
        self.blocked += 1
        return False

    def release(self, state: RequestState) -> None:
        if state.reserve_key:
            self.pool.release(state.reserve_key)
            state.reserve_key = ""

    def can_ever_admit(self, nbytes: int) -> bool:
        """Would the request fit in an *empty* pool — i.e. within the
        tiers' decoded-equivalent capacities? (deadlock guard)"""
        cap = 0.0
        for t in self.tiers:
            tier_cap = self.pool.occupancy(t)[1]
            if tier_cap is None:
                return True
            cap += tier_cap / self.pool.tier_scale(t, self.itemsize)
        return nbytes <= int(cap)


#: default specs for poisson_trace's mixed interactive/batch mode: tight
#: first-token deadline on the interactive class, pure-throughput batch
DEFAULT_INTERACTIVE_SLO = SLOSpec("interactive", ttft_deadline=8.0)
DEFAULT_BATCH_SLO = SLOSpec("batch")


def poisson_trace(n_requests: int, *, rate: float, vocab_size: int,
                  prompt_lens: Sequence[int] = (4, 24),
                  new_tokens: Sequence[int] = (2, 16),
                  prompt_quantum: int = 1,
                  long_prompt_lens: Optional[Sequence[int]] = None,
                  long_fraction: float = 0.0,
                  n_prefix_families: Optional[int] = None,
                  prefix_len: int = 0,
                  interactive_fraction: Optional[float] = None,
                  interactive_slo: Optional[SLOSpec] = None,
                  batch_slo: Optional[SLOSpec] = None,
                  seed: int = 0) -> List[Request]:
    """Deterministic mixed-length Poisson arrival trace (benchmarks/tests):
    exponential inter-arrival gaps at ``rate`` requests per unit of
    scheduler time, uniform prompt/decode lengths in the given ranges.

    ``prompt_quantum`` rounds every sampled prompt length **up** onto the
    quantum grid, clamped to the grid point at or below ``hi`` so a
    rounded length never exceeds an off-grid upper bound (a caller sizing
    ``hi`` against ``max_seq`` must not receive longer prompts than asked
    for): emitted lengths are multiples of ``prompt_quantum`` in
    ``[ceil(lo/q)*q, floor(hi/q)*q]``. A quantum larger than a range's
    upper bound has no on-grid length to emit and raises. (Rounding *down*
    with a ``max(lo, …)`` clamp — the old behavior — emitted the off-grid
    ``lo`` whenever ``lo`` was not a multiple, silently growing the set of
    prefill shapes bucketed serving has to compile.)

    ``long_prompt_lens`` + ``long_fraction`` mix a heavy tail of long
    prompts into the trace (same quantum grid): each request draws its
    length from ``long_prompt_lens`` with probability ``long_fraction`` —
    the stall-inducing traffic the chunked-prefill benchmark measures
    p99 step latency under. When ``long_prompt_lens`` is None the RNG
    call sequence is unchanged, so existing seeded traces stay
    byte-identical.

    ``n_prefix_families`` + ``prefix_len`` switch on **shared-prefix
    mode** (the prefix-cache benchmark's traffic shape): ``prefix_len``
    tokens are drawn once per family, and each request's prompt is one
    family's shared prefix followed by its own per-request suffix of the
    usual ``prompt_lens``-sampled length (total prompt = ``prefix_len`` +
    suffix — callers size ``max_seq`` accordingly). The family is drawn
    uniformly per request. When ``n_prefix_families`` is None the RNG call
    sequence is unchanged — seeded traces stay byte-identical.

    ``interactive_fraction`` switches on **mixed interactive/batch
    traffic** (the SLO-scheduling benchmark's shape): each request is
    annotated ``interactive_slo`` with that probability, else
    ``batch_slo`` (defaults: an ``interactive``-class spec with a tight
    TTFT deadline vs a deadline-free ``batch``-class spec). Class draws
    come from a *dedicated* RNG stream derived from ``seed``, so
    annotating a trace never perturbs its traffic: the arrivals, lengths
    and tokens of a seeded trace are byte-identical with the feature on,
    off, or before it existed — an SLO run and a FIFO baseline can share
    literally the same traffic."""
    if interactive_fraction is not None:
        if not 0.0 <= interactive_fraction <= 1.0:
            raise ValueError("interactive_fraction must be in [0, 1]")
        if interactive_slo is None:
            interactive_slo = DEFAULT_INTERACTIVE_SLO
        if batch_slo is None:
            batch_slo = DEFAULT_BATCH_SLO
    if n_prefix_families is not None:
        if n_prefix_families < 1:
            raise ValueError("n_prefix_families must be >= 1")
        if prefix_len < 1:
            raise ValueError("shared-prefix mode needs prefix_len >= 1")
    q = prompt_quantum
    for rng_name, rng_range in (("prompt_lens", prompt_lens),
                                ("long_prompt_lens", long_prompt_lens)):
        if rng_range is not None and (rng_range[1] // q) * q < rng_range[0]:
            raise ValueError(
                f"prompt_quantum {q} has no multiple inside {rng_name} "
                f"range {tuple(rng_range)}: no on-grid length can be "
                "emitted without violating a bound")
    rng = np.random.default_rng(seed)
    # separate stream for class annotation so it consumes none of the
    # traffic stream's draws (see docstring)
    cls_rng = (np.random.default_rng([seed, 0x510])
               if interactive_fraction is not None else None)
    prefixes = None
    if n_prefix_families is not None:
        prefixes = [rng.integers(0, vocab_size, size=prefix_len,
                                 dtype=np.int32)
                    for _ in range(n_prefix_families)]
    t = 0.0
    out: List[Request] = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        lo, hi = prompt_lens
        if long_prompt_lens is not None and rng.random() < long_fraction:
            lo, hi = long_prompt_lens
        s = int(rng.integers(lo, hi + 1))
        # round UP onto the quantum grid, but never past hi's grid floor
        s = min(-(-s // q) * q, (hi // q) * q)
        m = int(rng.integers(new_tokens[0], new_tokens[1] + 1))
        toks = rng.integers(0, vocab_size, size=s, dtype=np.int32)
        if prefixes is not None:
            fam = int(rng.integers(0, n_prefix_families))
            toks = np.concatenate([prefixes[fam], toks])
        slo = None
        if cls_rng is not None:
            slo = (interactive_slo
                   if cls_rng.random() < interactive_fraction
                   else batch_slo)
        out.append(Request(tokens=toks, max_new_tokens=m, arrival=t,
                           seed=i, slo=slo))
    return out
