"""Request-level continuous-batching scheduler with plan-driven KV prefetch.

The step loop joins and retires sequences **every decode step** (continuous
batching): a fixed pool of ``max_batch`` cache slots holds the running
requests; each step the scheduler

1. retires the handles of the previous step's plan-driven page fetches
   (``kv_offload`` mode) and reassembles the stacked decode cache;
2. admits queued requests — at most ``prefill_budget`` per step, so prompt
   prefill interleaves with decode instead of stalling it — if a slot is
   free AND the pool's device+host tiers can hold the request's worst-case
   pages (``AdmissionController``); admitted prompts are prefilled
   (batch-1) and scattered into their slot, and their first token sampled
   from the prefill logits exactly as ``ServeEngine.generate`` does;

   with **chunked prefill** (``chunk_size`` set) prompts instead advance
   ``chunk_size`` tokens per scheduler step through one fixed-shape
   ``jit_prefill_chunk`` executable (final partial chunks padded and
   masked): the PREFILL state persists across steps, the per-step budget
   is ``prefill_tokens`` *tokens* (default: one chunk) rather than a
   whole-prompt count, and the first token is sampled only when the last
   chunk lands — a long prompt can no longer stall every running decode
   for its full prefill, and mixed-length traffic compiles exactly one
   prefill executable instead of one per distinct prompt length. Between
   chunk steps the partial batch-1 row cache stays on the request state
   (resident) or is parked page-by-page through the pool (``kv_offload``),
   under the same ``L{i}.{j}`` labels the decode loop parks under;
3. decodes all running requests in ONE batched ``decode_step`` with
   per-row positions (rows are independent, so each row's tokens equal the
   per-request run), samples per request from its own seed-derived key
   stream, and retires requests that hit their budget — freeing slots for
   step 2 of the next iteration;
4. in ``kv_offload`` mode, parks every running request's pages back into
   the pool (stable per-page keys, priority = remaining decode budget — the
   pool's priority+LRU manager spills *cold* sequences' pages, those
   closest to retirement, to the host tier under device-tier pressure) and
   immediately issues the next step's fetches along the planner's refined
   order (``PlanPrefetcher``) — ahead of their consumers, with the next
   step's admission and prefill work between issue and wait, replacing the
   reactive store-then-immediately-wait round trip.

Time is a virtual clock (1.0 per step) so arrival traces and latency
measurements are deterministic; wall-clock throughput is the caller's to
measure around ``run``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import HardwareSpec, TPU_V5E
from repro.core.insertion import InsertionOptions
from repro.models.model import Model
from repro.obs.metrics import STEP_BUCKETS, MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.offload.kvcache import KVPageTable, worst_case_page_bytes
from repro.pool import MemoryPoolManager, auto_depth, default_pool
from repro.pool.manager import PoolEntry
from repro.prefix import PrefixCacheManager
from repro.sched.prefetch import InFlightFetches, PlanPrefetcher
from repro.sched.queue import AdmissionController, ArrivalQueue
from repro.sched.requests import (
    DECODE, DONE, PREEMPTED, PREFILL, SHED, Request, RequestState,
)
from repro.serving.engine import jit_decode, jit_prefill, jit_prefill_chunk
from repro.serving.sampling import sample_token
from repro.slo.admission import GoodputController
from repro.slo.policy import SLOConfig, candidate_key
from repro.slo.preempt import PreemptionEngine

#: pool priority of a preempted request's parked pages: below every live
#: sequence's pages (priority >= 1, their remaining work) but above the
#: prefix cache's 0.0 — device pressure spills preempted rows first.
_PREEMPTED_PAGE_PRIO = 0.25

_SCHED_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int = 4            # cache slots (concurrent requests)
    max_seq: int = 128            # per-slot cache capacity
    prefill_budget: int = 1       # prompts prefilled (joined) per step
    # chunked prefill: when chunk_size is set, prompts advance chunk_size
    # tokens per scheduler step (one fixed compiled shape; final partial
    # chunks padded+masked) and prefill_tokens is the per-step *token*
    # budget across requests (None → one chunk per step). prefill_budget
    # is ignored in chunked mode; None chunk_size keeps the legacy
    # whole-prompt path.
    chunk_size: Optional[int] = None
    prefill_tokens: Optional[int] = None
    kv_offload: bool = False      # pages live in the pool between steps
    cache_dtype: Any = jnp.float32
    hw: HardwareSpec = TPU_V5E    # cost model driving the prefetch plan
    # planner knobs for the prefetch plan; None → the paged default
    # (PAGED_INSERTION). A session-built scheduler gets these from its
    # OffloadConfig instead of the old call-site hard-coding.
    insert_opts: Optional[InsertionOptions] = None
    refine: bool = True
    # SLO-aware scheduling (repro.slo): None (or enable=False) keeps pure
    # FIFO + capacity admission; enabled, ready requests are admitted
    # best-first (priority class, then earliest TTFT deadline), certainly-
    # infeasible requests are shed, and deadline-pressed arrivals may
    # preempt (park) a running lower-priority sequence.
    slo: Optional[SLOConfig] = None


@dataclasses.dataclass
class SchedStats:
    steps: int = 0
    joins: int = 0
    retires: int = 0
    prefill_tokens: int = 0
    prefill_chunks: int = 0       # jit_prefill_chunk calls (chunked mode)
    decoded_tokens: int = 0
    pages_parked: int = 0
    cold_spills: int = 0          # our pages spilled down-tier by the manager
    prefix_hits: int = 0          # admissions that matched the prefix cache
    prefix_hit_tokens: int = 0    # prompt tokens served from cached prefixes
    preemptions: int = 0          # running sequences parked for a deadline
    resumes: int = 0              # preempted sequences restored to a slot
    shed: int = 0                 # requests dropped as deadline-infeasible


class ContinuousScheduler:
    def __init__(self, model: Model, params: Any,
                 cfg: SchedulerConfig = SchedulerConfig(), *,
                 pool: Optional[MemoryPoolManager] = None,
                 plan_cache: Optional[Dict[Any, Any]] = None,
                 prefix_cache: Optional[PrefixCacheManager] = None,
                 tracer=None,
                 metrics: Optional[MetricsRegistry] = None) -> None:
        self.model = model
        self.params = params
        self.cfg = cfg
        self._ns = f"sched{next(_SCHED_IDS)}"
        self.stats = SchedStats()
        self.finished: Dict[int, RequestState] = {}
        self._tracer = tracer if tracer is not None else NULL_TRACER
        # per-request latency histograms (virtual scheduler steps), shared
        # across a session's schedulers via the one registry
        self._metrics = metrics
        if metrics is not None:
            self._h_ttft = metrics.histogram(
                "req_ttft_steps", STEP_BUCKETS,
                "request arrival to first token, scheduler steps")
            self._h_queue_wait = metrics.histogram(
                "req_queue_wait_steps", STEP_BUCKETS,
                "request arrival to admission, scheduler steps")
            self._h_tpot = metrics.histogram(
                "req_time_per_output_token_steps",
                (0.25, 0.5, 1, 2, 4, 8, 16, 32),
                "mean per-output-token latency after the first token, "
                "scheduler steps")

        if cfg.chunk_size is not None:
            if not 1 <= cfg.chunk_size <= cfg.max_seq:
                raise ValueError(
                    f"chunk_size {cfg.chunk_size} must be in [1, max_seq="
                    f"{cfg.max_seq}]")
            if not model.supports_chunked_prefill():
                raise ValueError(
                    f"model {model.cfg.name!r} has recurrent or cross-"
                    "attention layers; chunked prefill supports attention/"
                    "MLA self-attention models only (leave chunk_size "
                    "unset for whole-prompt prefill)")
            self._chunk_prefill = jit_prefill_chunk(model)
        if cfg.prefill_tokens is not None:
            if cfg.chunk_size is None:
                raise ValueError("prefill_tokens (a per-step token budget) "
                                 "requires chunk_size")
            if cfg.prefill_tokens < 1:
                raise ValueError("prefill_tokens must be >= 1")
        self._prefill = jit_prefill(model)
        self._decode = jit_decode(model)
        self.cache = model.init_cache(cfg.max_batch, cfg.max_seq,
                                      cfg.cache_dtype)
        self.slots: List[Optional[RequestState]] = [None] * cfg.max_batch
        # flat layer index -> (segment, repeat, pattern position); matches
        # cfg.layer_specs() and the decode-graph layer numbering
        self._flat: List[Tuple[int, int, int]] = [
            (si, ri, pi)
            for si, seg in enumerate(model.cfg.segments)
            for ri in range(seg.repeats)
            for pi in range(len(seg.pattern))
        ]
        self._owns_pool = pool is None
        # one full step's page fetches (every leaf of every slot) must
        # issue before anything waits — the auto depth policy's `pages`
        pages = cfg.max_batch * sum(
            len(jax.tree.leaves(self.cache["segments"][si][f"p{pi}"]))
            for si, _, pi in self._flat)
        if pool is None:
            if cfg.kv_offload:
                raise ValueError(
                    "ContinuousScheduler(kv_offload=True) requires a pool; "
                    "construct schedulers through repro.api."
                    "HyperOffloadSession.scheduler (mode='kv_offload')")
            pool = default_pool(transfer_depth=auto_depth(pages=pages))
        elif cfg.kv_offload:
            # shared (session) pool: grow the engine to cover this consumer
            pool.transfer.ensure_depth(auto_depth(pages=pages))
        self.pool = pool
        self._plan_cache = plan_cache
        self.queue = ArrivalQueue()
        # worst-case reservation is in decoded bytes; itemsize lets the
        # ledger count codec-wrapped tiers at decoded-equivalent capacity
        self.admission = AdmissionController(
            self.pool, itemsize=jnp.dtype(cfg.cache_dtype).itemsize)
        self._row_bytes = worst_case_page_bytes(
            model.cache_specs(1, cfg.max_seq, cfg.cache_dtype))
        # SLO-aware scheduling (repro.slo): policy objects + the parked
        # (preempted) states, which are in neither the queue nor a slot
        # but still hold their capacity reservation
        self.slo: Optional[SLOConfig] = \
            cfg.slo if (cfg.slo is not None and cfg.slo.enable) else None
        self.preempted: List[RequestState] = []
        self.goodput: Optional[GoodputController] = None
        self.preemptor: Optional[PreemptionEngine] = None
        if self.slo is not None:
            self.goodput = GoodputController(self.slo, metrics=metrics)
            self.preemptor = PreemptionEngine(self.slo)
        self.prefetcher: Optional[PlanPrefetcher] = None
        self._inflight: Optional[InFlightFetches] = None
        self._fetch_map: Dict[str, Tuple[int, int, int, int, int]] = {}
        if cfg.kv_offload:
            self.prefetcher = PlanPrefetcher(
                model.cfg, cfg.max_batch, cfg.max_seq, pool=self.pool,
                hw=cfg.hw, refine=cfg.refine, insert_opts=cfg.insert_opts,
                plan_cache=plan_cache, tracer=self._tracer)
            self.pool.add_evict_listener(self._on_evict)
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            if cfg.chunk_size is None:
                raise ValueError(
                    "prefix_cache requires chunked prefill (chunk_size): a "
                    "hit resumes prefill at the match offset, which only "
                    "the chunked path supports")
            if cfg.kv_offload and prefix_cache.pool is not self.pool:
                raise ValueError(
                    "prefix_cache must share the scheduler's pool in "
                    "kv_offload mode (prefix-page fetches ride the same "
                    "PlanPrefetcher plan)")
            # prefix reuse slices/restores KV by absolute position, which
            # is only exact while no cache leaf's ring buffer has wrapped:
            # requests longer than the shortest leaf (a sliding-window
            # layer's window) bypass the cache entirely
            self._prefix_seq_limit = min(
                int(leaf.shape[2]) for leaf in jax.tree.leaves(self.cache))
        self.now = 0.0
        self._closed = False

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> RequestState:
        if request.total_len > self.cfg.max_seq:
            raise ValueError(
                f"request {request.req_id}: prompt+decode "
                f"{request.total_len} exceeds max_seq {self.cfg.max_seq}")
        if self._tracer.enabled:
            self._tracer.instant("request", "QUEUED",
                                 {"req": request.req_id,
                                  "prompt_len": request.prompt_len,
                                  "arrival": request.arrival})
        return self.queue.push(request)

    @property
    def active(self) -> List[RequestState]:
        return [s for s in self.slots if s is not None]

    def close(self) -> None:
        """Idempotent shutdown: drop remaining pages, unhook from a shared
        pool, close an owned pool."""
        if self._closed:
            return
        self._closed = True
        if self.cfg.kv_offload:
            self.pool.remove_evict_listener(self._on_evict)
        for st in (list(self.slots) + list(self.preempted)
                   + list(self.finished.values())):
            if st is not None and st.pages is not None:
                st.pages.drop()
            if st is not None:
                if st.prefix_hit is not None and self.prefix_cache is not None:
                    self.prefix_cache.release(st.prefix_hit)
                self.admission.release(st)
        if self._owns_pool:
            self.pool.close()

    def pool_stats(self) -> Dict[str, Any]:
        return self.pool.snapshot()

    def prefetch_stats(self) -> Optional[Dict[str, float]]:
        return None if self.prefetcher is None else \
            self.prefetcher.stats.snapshot()

    def prefix_stats(self) -> Optional[Dict[str, float]]:
        return None if self.prefix_cache is None else \
            self.prefix_cache.snapshot()

    # -- step phases ---------------------------------------------------
    def _on_evict(self, entry: PoolEntry, dst: str) -> None:
        if entry.key.startswith(self._ns + "/"):
            self.stats.cold_spills += 1

    def _subtree(self, si: int, pi: int):
        return self.cache["segments"][si][f"p{pi}"]

    def _collect_inflight(self) -> None:
        """Wait (in the plan's consumption order) on the fetches issued at
        the end of the previous step and scatter the pages back into the
        stacked cache."""
        fetched = self._inflight.wait_all()
        self._inflight = None
        updates: Dict[Tuple[int, int], List[Tuple[int, int, int, jax.Array]]] = {}
        for key, arr in fetched.items():
            dest = self._fetch_map.get(key)
            if dest is None:
                # the owner was preempted after these fetches were issued:
                # its slot may already hold another request, so the value
                # is dropped (the page itself stays pool-resident from the
                # last park — restore re-fetches it)
                continue
            si, pi, j, ri, slot = dest
            updates.setdefault((si, pi), []).append((j, ri, slot, arr))
        self._fetch_map = {}
        for (si, pi), ups in updates.items():
            leaves, treedef = jax.tree.flatten(self._subtree(si, pi))
            for j, ri, slot, arr in ups:
                leaves[j] = leaves[j].at[ri, slot].set(arr)
            self.cache["segments"][si][f"p{pi}"] = jax.tree.unflatten(
                treedef, leaves)

    def _reserve_capacity(self, state: RequestState) -> bool:
        """Worst-case capacity reservation shared by every admission path
        (the request's page-key prefix ``covers`` its future parked pages
        — "-" guards req3 vs req30). False = capacity pressure."""
        covers = f"{self._ns}/req{state.req_id}-"
        if self.admission.try_admit(state, self._row_bytes, covers):
            return True
        if (not self.active and not self.preempted
                and not self.admission.can_ever_admit(self._row_bytes)):
            raise RuntimeError(
                f"request {state.req_id} can never be admitted: "
                f"worst-case pages ({self._row_bytes} B) exceed the "
                "pool's device+host capacity")
        return False   # retirements will free it

    def _try_admit_head(self) -> Optional[Tuple[RequestState, int]]:
        """Admission guard shared by both prefill paths: pop the arrival
        queue's best candidate into a free slot (SLO mode: possibly freed
        by preemption) if the pool can hold its worst-case pages. Returns
        (state, slot) or None (no slot / not arrived / capacity
        pressure)."""
        if self.slo is not None:
            return self._try_admit_slo()
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return None
        state = self.queue.head_ready(self.now)
        if state is None:
            return None
        if not self._reserve_capacity(state):
            return None
        self.queue.pop()
        return state, free[0]

    def _try_admit_slo(self) -> Optional[Tuple[RequestState, int]]:
        """SLO admission: the best ready candidate (priority class, then
        earliest TTFT deadline — ``slo.candidate_key``) takes a free slot,
        or — when none is free and its deadline can't survive waiting for
        a natural retirement — a slot freed by preempting a running
        lower-priority sequence. Capacity is reserved *before* the
        preemption is performed, so a reservation failure never parks a
        victim for nothing."""
        ready = self.queue.ready(self.now)
        if not ready:
            return None
        state = min(ready, key=candidate_key)
        free = [i for i, s in enumerate(self.slots) if s is None]
        if free:
            if not self._reserve_capacity(state):
                return None
            self.queue.remove(state)
            return state, free[0]
        running = self.active
        if self.cfg.kv_offload:
            # a sequence that reached DECODE *this step* (prefill just
            # finished) has its freshest row only in the stacked cache —
            # its pool pages aren't parked until this step's epilogue —
            # so it is not preemptible yet
            running = [s for s in running
                       if not (s.status == DECODE
                               and s.last_step == self.stats.steps)]
        victim = self.preemptor.pick_victim(
            state, running, self.now,
            est_prefill_steps=self._est_prefill_steps(state),
            remaining_steps=self._remaining_steps)
        if victim is None:
            return None
        if not self._reserve_capacity(state):
            return None
        slot = victim.slot
        self._preempt(victim)
        self.queue.remove(state)
        return state, slot

    # -- SLO mechanics -------------------------------------------------
    def _est_prefill_steps(self, state: RequestState) -> float:
        """Optimistic steps from admission to first token for a queued
        candidate: its remaining prompt plus the prompt backlog already
        mid-prefill, at the measured per-step prefill rate. Whole-prompt
        mode prefills in the admission step itself."""
        if self.cfg.chunk_size is None:
            return 1.0
        base = self.cfg.prefill_tokens or self.cfg.chunk_size
        rate = self.goodput.rate(base)
        backlog = sum(max(s.request.prompt_len - s.prefill_pos, 0)
                      for s in self.slots
                      if s is not None and s.status == PREFILL)
        remaining = max(state.request.prompt_len - state.prefill_pos, 0)
        return max(1.0, np.ceil((backlog + remaining) / rate))

    def _remaining_steps(self, s: RequestState) -> int:
        """Steps until a running state retires and frees its slot (decode
        budget plus, mid-prefill, its outstanding chunks)."""
        n = s.request.max_new_tokens - len(s.out)
        if s.status == PREFILL and self.cfg.chunk_size is not None:
            base = self.cfg.prefill_tokens or self.cfg.chunk_size
            rem = max(s.request.prompt_len - s.prefill_pos, 0)
            n += -(-rem // base)
        return n

    def _slo_shed_sweep(self) -> None:
        """Drop every ready request whose TTFT deadline is certainly
        unmeetable — *before* admission, so no prefill is spent on
        admitted-then-missed work."""
        for state in self.queue.ready(self.now):
            if self.goodput.infeasible(
                    state, self.now, self._est_prefill_steps(state)):
                self._shed(state)

    def _shed(self, state: RequestState) -> None:
        """Terminal drop from the queue: never admitted, so there is no
        slot, reservation, or page to release."""
        self.queue.remove(state)
        state.status = SHED
        state.t_done = self.now
        self.finished[state.req_id] = state
        self.stats.shed += 1
        self.goodput.note_retired(state)
        if self._tracer.enabled:
            self._tracer.instant("request", "SHED",
                                 {"req": state.req_id,
                                  "arrival": state.request.arrival})

    def _preempt(self, victim: RequestState) -> None:
        """Park a running sequence and free its slot. A DECODE victim's
        rows are either already pool-resident from the last ``_park_and_
        issue`` (kv_offload — just demote their priority and orphan any
        in-flight fetches targeting the reassigned slot) or sliced out of
        the stacked cache onto ``chunk_cache`` (resident). A mid-PREFILL
        victim's partial row is already on ``chunk_cache``/in the pool
        (``_park_chunk_row`` ran when the chunk budget moved on). The
        capacity reservation is kept — the pages still occupy pool space,
        so admission stays exactly as conservative as before."""
        slot = victim.slot
        if victim.status == DECODE and not self.cfg.kv_offload:
            victim.chunk_cache = jax.tree.map(
                lambda big: big[:, slot:slot + 1], self.cache)
        if self.cfg.kv_offload and victim.pages is not None:
            for key in victim.pages.keys.values():
                self._fetch_map.pop(key, None)
                self.pool.set_priority(key, _PREEMPTED_PAGE_PRIO)
        victim.status = PREEMPTED
        victim.preemptions += 1
        victim.slot = None
        self.slots[slot] = None
        self.preempted.append(victim)
        self.stats.preemptions += 1
        if self._tracer.enabled:
            self._tracer.instant("request", "PREEMPTED",
                                 {"req": victim.req_id, "slot": slot})

    def _resume_preempted(self, *, final: bool) -> None:
        """Restore preempted sequences into free slots, best first. In the
        pre-pass (``final=False``) a preempted sequence only takes a slot
        if it outranks every ready queued candidate — otherwise admission
        gets first claim on the slot this step; the post-pass
        (``final=True``) hands any slots admission left free back to
        preempted work (its capacity is already reserved)."""
        while self.preempted:
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                return
            best = min(self.preempted, key=candidate_key)
            if not final:
                ready = self.queue.ready(self.now)
                if ready and min(candidate_key(s) for s in ready) \
                        < candidate_key(best):
                    return
            # by identity: dataclass equality would compare token arrays
            self.preempted = [s for s in self.preempted if s is not best]
            self._resume(best, free[0])

    def _resume(self, state: RequestState, slot: int) -> None:
        """Inverse of ``_preempt``: a DECODE sequence's row rides the same
        restore path parked mid-prefill chunks use (chunk_cache or plan-
        driven pool fetches) and is scattered back into the slot; a mid-
        PREFILL sequence just re-enters the chunked loop, which restores
        its row on its next advance."""
        was_decode = state.t_first_token is not None
        self.slots[slot] = state
        state.slot = slot
        state.status = DECODE if was_decode else PREFILL
        self.stats.resumes += 1
        if was_decode:
            row = self._restore_chunk_row(state)
            self.cache = jax.tree.map(
                lambda big, r: big.at[:, slot].set(r[:, 0]),
                self.cache, row)
        if self._tracer.enabled:
            self._tracer.instant("request", "RESUMED",
                                 {"req": state.req_id, "slot": slot})

    def slo_snapshot(self) -> Optional[Dict[str, int]]:
        return None if self.goodput is None else self.goodput.snapshot()

    def _admit_and_prefill(self) -> List[Tuple[int, int]]:
        if self.slo is not None:
            # SLO pre-pass: reset the preemption quota, shed certainly-
            # infeasible arrivals before any admission work, and restore
            # preempted sequences that outrank everything still queued
            pt0 = self.stats.prefill_tokens
            self.preemptor.begin_step()
            self._slo_shed_sweep()
            self._resume_preempted(final=False)
        if self.cfg.chunk_size is not None:
            emitted = self._admit_and_prefill_chunked()
        else:
            emitted = []
            for _ in range(self.cfg.prefill_budget):
                admitted = self._try_admit_head()
                if admitted is None:
                    break
                emitted.append(self._join(*admitted))
        if self.slo is not None:
            # slots admission left free (no ready candidates / capacity)
            # go back to preempted sequences, and the step's landed
            # prefill tokens feed the measured-rate estimate
            self._resume_preempted(final=True)
            self.goodput.note_step(self.stats.prefill_tokens - pt0)
        return emitted

    def _admit_and_prefill_chunked(self) -> List[Tuple[int, int]]:
        """Chunked admission/prefill: spend up to ``prefill_tokens`` chunk
        tokens this step — first advancing requests already mid-PREFILL
        (oldest join first, so prompts finish in admission order), then
        admitting new ones while budget remains. Each ``jit_prefill_chunk``
        call charges a full ``chunk_size`` against the budget (a padded
        final chunk costs the same compute as a full one); the first chunk
        of a step always runs even if the budget is smaller than one chunk,
        so the loop can't stall."""
        emitted: List[Tuple[int, int]] = []
        budget = self.cfg.prefill_tokens or self.cfg.chunk_size
        mid = [s for s in self.slots
               if s is not None and s.status == PREFILL]
        if self.goodput is not None:
            # deadline pressure on mid-prefill requests may raise the
            # step's token budget (capped at max_prefill_boost)
            budget = self.goodput.boost_budget(budget, mid, self.now)
        spent = 0
        for s in sorted(mid, key=lambda s: (s.joined_step, s.req_id)):
            out, spent = self._advance_chunks(s, spent, budget)
            emitted += out
        # SLO mode: mid-prefill work exhausting the budget must not hide
        # the admission (and preemption) check from a deadline-pressed
        # arrival — it still gets one seat attempt; its own chunks then
        # start next step
        tries = 0
        while spent < budget or (self.slo is not None and tries == 0):
            tries += 1
            admitted = self._try_admit_head()
            if admitted is None:
                break
            state, slot = admitted
            self._join_chunked(state, slot)
            out, spent = self._advance_chunks(state, spent, budget)
            emitted += out
        return emitted

    def _advance_chunks(self, state: RequestState, spent: int,
                        budget: int) -> Tuple[List[Tuple[int, int]], int]:
        """Advance one request as far as the step's token budget allows,
        holding its row cache resident across consecutive chunks — the row
        parks (once) only when the budget moves on with the prompt still
        unfinished, not once per chunk."""
        emitted: List[Tuple[int, int]] = []
        row = None
        while state.status == PREFILL and spent < budget:
            if row is None:
                row = self._restore_chunk_row(state)
            out, row = self._prefill_chunk_step(state, row)
            emitted += out
            spent += self.cfg.chunk_size
        if row is not None:
            self._park_chunk_row(state, row)
        return emitted, spent

    def _join_chunked(self, state: RequestState, slot: int) -> None:
        """Take the slot and the capacity reservation; prefill advances in
        ``_prefill_chunk_step`` calls from here on. With a prefix cache, a
        hit pre-loads the shared pages and moves ``prefill_pos`` past
        them — only the uncached suffix is ever prefilled."""
        self._take_slot(state, slot)
        state.prefill_pos = 0
        state.chunk_cache = self.model.init_cache(1, self.cfg.max_seq,
                                                  self.cfg.cache_dtype)
        if self.prefix_cache is not None:
            self._apply_prefix_hit(state)

    def _apply_prefix_hit(self, state: RequestState) -> None:
        """Admission-side prefix hit: match the prompt, *copy* every shared
        page into the request's own row cache (the copy is what makes the
        sharing copy-on-write — the cached entries are never written
        again), and resume prefill at the match offset. The match is capped
        at ``prompt_len - 1`` so at least one real token remains to prefill
        (the first sampled token needs its logits). Read refs on the
        matched pages are held until retirement."""
        req = state.request
        if req.total_len > self._prefix_seq_limit:
            return   # a ring-buffer leaf would wrap — positions unreliable
        hit = self.prefix_cache.lookup(req.tokens,
                                       max_tokens=req.prompt_len - 1)
        if hit is None:
            return
        state.prefix_hit = hit
        pages = hit.page_keys()
        values = self._fetch_prefix_pages(pages)
        ps = self.prefix_cache.page_size
        row = state.chunk_cache
        for i, (si, ri, pi) in enumerate(self._flat):
            leaves, treedef = jax.tree.flatten(row["segments"][si][f"p{pi}"])
            for j in range(len(leaves)):
                for p, entries in enumerate(pages):
                    arr = values[entries[f"L{i}.{j}"]]
                    leaves[j] = leaves[j].at[
                        ri, 0, p * ps:(p + 1) * ps].set(arr)
            row["segments"][si][f"p{pi}"] = jax.tree.unflatten(treedef, leaves)
        state.prefill_pos = hit.tokens
        self.stats.prefix_hits += 1
        self.stats.prefix_hit_tokens += hit.tokens

    def _fetch_prefix_pages(self, pages: List[Dict[str, str]]) -> Dict[str, Any]:
        """Materialize the matched pages' arrays. Host/remote-resident hits
        ride the ``PlanPrefetcher`` plan (kv_offload mode): every page's
        fetch issues in the refined order before any is waited on. Pages
        the plan doesn't cover — and all pages in resident mode — fall back
        to a sync pool get. Arrays are decommitted (NumPy) so the scatter
        into the row cache keeps the one-executable jit signature."""
        keys_by_layer: Dict[int, List[str]] = {}
        all_keys: List[str] = []
        for entries in pages:
            for label, key in entries.items():
                layer = int(label[1:label.index(".")])
                keys_by_layer.setdefault(layer, []).append(key)
                all_keys.append(key)
        fetched: Dict[str, Any] = {}
        if self.prefetcher is not None:
            fetched = self.prefetcher.issue(keys_by_layer).wait_all()
        pool = self.prefix_cache.pool
        return {k: np.asarray(fetched[k] if k in fetched else pool.get(k))
                for k in all_keys}

    def _prefill_chunk_step(
            self, state: RequestState,
            row: Any) -> Tuple[List[Tuple[int, int]], Optional[Any]]:
        """Advance one request by one chunk against its row cache. Returns
        (emitted, row): the advanced row while the prompt is unfinished
        (the caller keeps it resident or parks it), or None once the final
        chunk lands — then the row is scattered into the batch slot and the
        first token sampled from the last valid token's logits, exactly as
        whole-prompt ``_join`` does, so token identity is preserved."""
        req = state.request
        chunk = self.cfg.chunk_size
        start = state.prefill_pos
        end = min(start + chunk, req.prompt_len)
        valid = end - start
        toks = np.zeros((1, chunk), np.int32)
        toks[0, :valid] = req.tokens[start:end]
        logits, row = self._chunk_prefill(
            self.params, {"tokens": jnp.asarray(toks)},
            jnp.int32(start), jnp.int32(valid), row)
        state.prefill_pos = end
        state.last_step = self.stats.steps
        self.stats.prefill_tokens += valid
        self.stats.prefill_chunks += 1
        if end < req.prompt_len:
            return [], row
        # last chunk landed — shared completion with the whole-prompt path
        state.chunk_cache = None
        return [self._finish_prefill(state, logits, row)], None

    def _park_chunk_row(self, state: RequestState, row: Any) -> None:
        """Between chunk steps the partial row cache stays on the state
        (resident) or is parked page-by-page through the pool (kv_offload)
        — same ``L{i}.{j}`` labels the decode loop parks under, so once
        decoding starts the entries are replaced in place. Priority =
        remaining work (all decode steps plus unprefilled prompt tokens):
        mid-prefill rows are the hottest pages in the pool."""
        if not self.cfg.kv_offload:
            state.chunk_cache = row
            return
        prio = float(state.request.max_new_tokens
                     + state.request.prompt_len - state.prefill_pos)
        with self._tracer.span("sched", "park_row", req=state.req_id):
            for i, (si, ri, pi) in enumerate(self._flat):
                leaves = jax.tree.leaves(row["segments"][si][f"p{pi}"])
                for j, leaf in enumerate(leaves):
                    state.pages.park(f"L{i}.{j}", leaf[ri, 0],
                                     self.pool.top_tier, priority=prio)
                    self.stats.pages_parked += 1
        state.chunk_cache = None

    def _restore_chunk_row(self, state: RequestState) -> Any:
        """Inverse of ``_park_chunk_row``: the resident row is handed back
        directly (and detached — jit donates it); a parked row rides the
        ``PlanPrefetcher`` plan — every page's fetch issues in the refined
        order before any is waited on, the same async path decode pages
        take, instead of the old page-by-page sync round trip."""
        if state.chunk_cache is not None:
            row, state.chunk_cache = state.chunk_cache, None
            return row
        with self._tracer.span("sched", "restore_row", req=state.req_id):
            return self._restore_parked_row(state)

    def _restore_parked_row(self, state: RequestState) -> Any:
        row = self.model.init_cache(1, self.cfg.max_seq, self.cfg.cache_dtype)
        keys_by_layer: Dict[int, List[str]] = {}
        for i, (si, ri, pi) in enumerate(self._flat):
            n = len(jax.tree.leaves(row["segments"][si][f"p{pi}"]))
            keys_by_layer.setdefault(i, []).extend(
                state.pages.key_of(f"L{i}.{j}") for j in range(n))
        fetched: Dict[str, Any] = {}
        if self.prefetcher is not None:
            fetched = self.prefetcher.issue(keys_by_layer).wait_all()
        for i, (si, ri, pi) in enumerate(self._flat):
            leaves, treedef = jax.tree.flatten(row["segments"][si][f"p{pi}"])
            for j in range(len(leaves)):
                # layers outside the plan fall back to a sync fetch; either
                # way pages come back committed to their tier's device, so
                # strip the commitment (NumPy) so restored rows share the
                # (uncommitted) jit signature of fresh/resident rows — one
                # compiled chunk executable per chunk shape, not one per
                # residency path
                val = fetched.get(state.pages.key_of(f"L{i}.{j}"))
                if val is None:
                    val = state.pages.fetch(f"L{i}.{j}")
                leaves[j] = leaves[j].at[ri, 0].set(np.asarray(val))
            row["segments"][si][f"p{pi}"] = jax.tree.unflatten(treedef, leaves)
        return row

    def _take_slot(self, state: RequestState, slot: int) -> None:
        """Join bookkeeping shared by both prefill paths: occupy the batch
        slot and (kv_offload) create the request's page table."""
        state.status = PREFILL
        state.slot = slot
        self.slots[slot] = state
        state.joined_step = self.stats.steps
        state.t_joined = self.now
        if self.cfg.kv_offload:   # resident mode never parks a page
            state.pages = KVPageTable(
                self.pool, f"{self._ns}/req{state.req_id}")
        self.stats.joins += 1
        if self._tracer.enabled:
            self._tracer.instant("request", "PREFILL",
                                 {"req": state.req_id, "slot": slot})

    def _finish_prefill(self, state: RequestState, logits: jax.Array,
                        row: Any) -> Tuple[int, int]:
        """Prompt fully prefilled (whole prompt, or the final chunk):
        scatter the batch-1 row into the slot and sample the first token
        from the last prompt token's logits, exactly as
        ``ServeEngine.generate`` does — ONE shared implementation, so the
        whole-prompt and chunked paths cannot drift apart on the token-
        identity-critical sampling and state transition."""
        req = state.request
        self.cache = jax.tree.map(
            lambda big, r: big.at[:, state.slot].set(r[:, 0]),
            self.cache, row)
        key = state.sample_key() if req.temperature > 0.0 else None
        tok = int(sample_token(logits[:, 0], key,
                               temperature=req.temperature,
                               top_k=req.top_k)[0])
        state.out.append(tok)
        state.last_tok = tok
        state.pos = req.prompt_len    # next decode writes here
        state.t_first_token = self.now
        state.status = DECODE
        state.last_step = self.stats.steps
        if self._tracer.enabled:
            self._tracer.instant("request", "DECODE", {"req": req.req_id})
        if state.done:                # max_new_tokens == 1
            self._retire(state)
        return (req.req_id, tok)

    def _join(self, state: RequestState, slot: int) -> Tuple[int, int]:
        req = state.request
        self._take_slot(state, slot)
        row = self.model.init_cache(1, self.cfg.max_seq, self.cfg.cache_dtype)
        logits, row = self._prefill(
            self.params, {"tokens": jnp.asarray(req.tokens[None, :])}, row)
        self.stats.prefill_tokens += req.prompt_len
        return self._finish_prefill(state, logits, row)

    def _decode_active(self) -> List[Tuple[int, int]]:
        live = [s for s in self.slots if s is not None and s.status == DECODE]
        if not live:
            return []
        b = self.cfg.max_batch
        tok = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        for s in live:
            tok[s.slot, 0] = s.last_tok
            pos[s.slot] = s.pos
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok), jnp.asarray(pos))
        emitted: List[Tuple[int, int]] = []
        greedy = None   # one batched argmax serves every temperature-0 row
        for s in live:
            req = s.request
            if req.temperature <= 0.0:
                if greedy is None:
                    greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
                t = int(greedy[s.slot])
            else:
                t = int(sample_token(logits[s.slot:s.slot + 1, 0],
                                     s.sample_key(),
                                     temperature=req.temperature,
                                     top_k=req.top_k)[0])
            s.out.append(t)
            s.last_tok = t
            s.pos += 1
            s.last_step = self.stats.steps
            self.stats.decoded_tokens += 1
            emitted.append((req.req_id, t))
            if s.done:
                self._retire(s)
        return emitted

    def _retire(self, state: RequestState) -> None:
        state.status = DONE
        state.t_done = self.now
        arrival = state.request.arrival
        if self._metrics is not None:
            self._h_ttft.observe(state.t_first_token - arrival)
            self._h_queue_wait.observe(state.t_joined - arrival)
            self._h_tpot.observe((state.t_done - state.t_first_token)
                                 / max(len(state.out) - 1, 1))
        if self._tracer.enabled:
            self._tracer.instant("request", "DONE",
                                 {"req": state.req_id,
                                  "tokens": len(state.out),
                                  "ttft_steps": state.t_first_token - arrival,
                                  "latency_steps": state.t_done - arrival})
        if self.prefix_cache is not None:
            self._donate_prefix(state)
            if state.prefix_hit is not None:
                self.prefix_cache.release(state.prefix_hit)
        if state.pages is not None:
            state.pages.drop()
        self.admission.release(state)
        self.slots[state.slot] = None
        state.slot = None
        self.finished[state.req_id] = state
        self.stats.retires += 1
        if self.goodput is not None:
            self.goodput.note_retired(state)

    def _donate_prefix(self, state: RequestState) -> None:
        """Retirement-side donation: the retired prompt's full prefix pages
        enter the cache instead of being freed. The stacked decode cache
        still holds this slot's rows (retire runs right after the decode or
        final-chunk scatter), so pages are sliced straight out of it —
        decode only ever writes at positions >= prompt_len, so prompt-range
        slices are exactly the prefill-time KV. ``extract`` is lazy: the
        manager calls it only for pages not already cached."""
        req = state.request
        if req.total_len > self._prefix_seq_limit:
            return
        n_pages = req.prompt_len // self.prefix_cache.page_size
        if n_pages < 1:
            return
        slot, ps = state.slot, self.prefix_cache.page_size

        def extract(p: int) -> Dict[str, jax.Array]:
            a, b = p * ps, (p + 1) * ps
            page: Dict[str, jax.Array] = {}
            for i, (si, ri, pi) in enumerate(self._flat):
                leaves = jax.tree.leaves(self._subtree(si, pi))
                for j, leaf in enumerate(leaves):
                    page[f"L{i}.{j}"] = leaf[ri, slot, a:b]
            return page

        self.prefix_cache.donate(req.tokens, n_pages, extract)

    def _park_and_issue(self) -> None:
        """kv_offload epilogue: park every running request's pages (stable
        keys), then issue the next step's fetches along the plan.

        Page priority = the request's remaining decode budget: every
        device-resident page saves one host fetch per remaining step, so
        the manager's priority+LRU eviction spills the *coldest* sequences
        — those with the least future work, closest to retirement — first
        under device-tier pressure."""
        live = [s for s in self.slots if s is not None and s.status == DECODE]
        keys_by_layer: Dict[int, List[str]] = {}
        self._fetch_map = {}
        for s in live:
            prio = float(s.request.max_new_tokens - len(s.out))
            for i, (si, ri, pi) in enumerate(self._flat):
                leaves = jax.tree.leaves(self._subtree(si, pi))
                for j, leaf in enumerate(leaves):
                    key = s.pages.park(f"L{i}.{j}", leaf[ri, s.slot],
                                       self.pool.top_tier, priority=prio)
                    keys_by_layer.setdefault(i, []).append(key)
                    self._fetch_map[key] = (si, pi, j, ri, s.slot)
                    self.stats.pages_parked += 1
        if keys_by_layer:
            self._inflight = self.prefetcher.issue(keys_by_layer)

    # ------------------------------------------------------------------
    def replan(self, hw) -> None:
        """Swap in a prefetch plan computed under ``hw`` — the session's
        calibration loop calls this after measuring real per-tier transfer
        rates, so the refined issue order and plan leads reflect measured
        bandwidth rather than the static spec the scheduler was built
        with. No-op in resident mode (nothing is planned). Safe at a step
        boundary: parked pages keep their keys; only the *order* future
        fetches issue in (and the plan cached under the new spec's name)
        changes. Counters carry over so per-step rates stay meaningful."""
        self.cfg = dataclasses.replace(self.cfg, hw=hw)
        if self.prefetcher is None:
            return
        old_stats = self.prefetcher.stats
        self.prefetcher = PlanPrefetcher(
            self.model.cfg, self.cfg.max_batch, self.cfg.max_seq,
            pool=self.pool, hw=hw, refine=self.cfg.refine,
            insert_opts=self.cfg.insert_opts, plan_cache=self._plan_cache,
            tracer=self._tracer)
        self.prefetcher.stats.steps = old_stats.steps
        self.prefetcher.stats.fetches_issued = old_stats.fetches_issued

    def step(self) -> List[Tuple[int, int]]:
        """One scheduler step. Returns the (req_id, token) pairs emitted.

        Admission + prefill run *before* the in-flight fetches are waited
        on: that host/prefill work sits between the previous step's issue
        and this step's wait, so the transfers it overlaps are real. A
        newly admitted slot was free when the fetches were issued, so the
        joiner's freshly scattered rows are never clobbered by collect."""
        tr = self._tracer
        with tr.span("sched", "step", step=self.stats.steps):
            with tr.span("sched", "admit_prefill"):
                emitted = self._admit_and_prefill()
            if self._inflight is not None:
                # waits on the previous step's plan-driven fetches happen
                # here — the overlap analyzer charges their exposure to
                # this step's span
                with tr.span("sched", "collect"):
                    self._collect_inflight()
            with tr.span("sched", "decode"):
                emitted += self._decode_active()
            if self.cfg.kv_offload:
                with tr.span("sched", "park_issue"):
                    self._park_and_issue()
        self.stats.steps += 1
        self.now += 1.0
        return emitted

    def default_max_steps(self) -> int:
        """No-progress bound over everything queued + running: per request
        its decode budget, plus every prefill chunk still outstanding
        (chunked mode can spend whole steps advancing one prompt
        ``chunk_size`` tokens at a time). Shared by ``run`` and external
        drivers (the serving benchmark) so the formula cannot drift."""
        def _steps_for(s: RequestState) -> int:
            n = s.request.max_new_tokens + 1
            if self.cfg.chunk_size is not None:
                rem = max(s.request.prompt_len - s.prefill_pos, 0)
                n += -(-rem // self.cfg.chunk_size)   # ceil
            return n
        return 16 + 2 * sum(
            _steps_for(s) for s in (list(self.queue.pending()) + self.active
                                    + list(self.preempted)))

    def run(self, requests: Sequence[Request] = (), *,
            max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive the loop until every submitted request completes. Returns
        req_id -> generated token ids."""
        for r in requests:
            self.submit(r)
        if max_steps is None:
            max_steps = self.default_max_steps()
        steps = 0
        while len(self.queue) or self.active or self.preempted:
            if (not self.active and not self.preempted
                    and self.queue.head_ready(self.now) is None):
                self.now = max(self.now, self.queue.next_arrival())  # idle skip
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler made no progress "
                                   f"({steps} steps, {len(self.queue)} queued)")
        return {rid: st.tokens_array() for rid, st in self.finished.items()}
