"""Plan-driven KV prefetch for the serving scheduler (§4.3 at runtime).

``PlanPrefetcher`` asks the compiler for a decode-step plan once — it
builds the layer-level decode graph (``core.tracer.trace_decode_step``
with pool-resident KV), runs ``HyperOffloadPlanner`` (cache-op insertion +
Algorithm 1 order refinement) — and then *executes the plan's cache-op
schedule* every serving step: walking the refined order, each
``prefetch::kv_i`` node issues the async ``TransferEngine`` fetches for
layer *i*'s pages at its scheduled slot, which Algorithm 1 placed ahead of
the consuming layer's compute. The consumer waits on the handles in layer
order, so layer *l+1*'s pages are in flight while layer *l*'s are being
consumed, and the scheduler puts the next step's admission and prefill
work between issue and wait — replacing the reactive
store-then-immediately-wait round trip (`ServeEngine._cache_round_trip`)
the paper argues against.

On CPU the "overlap" is thread-level (transfer workers run under the main
thread's decode dispatch); as with the pool executor, semantics and
traffic are what we validate here — the timeline simulator quantifies the
real overlap.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.core.costmodel import HardwareSpec, TPU_V5E
from repro.core.insertion import PAGED_INSERTION, InsertionOptions
from repro.core.planner import HyperOffloadPlanner, OffloadPlan
from repro.core.tracer import TraceOptions, trace_decode_step
from repro.obs.trace import NULL_TRACER
from repro.pool.manager import MemoryPoolManager
from repro.pool.transfer import TransferHandle


@dataclass
class InFlightFetches:
    """One step's issued page fetches: handles keyed by pool key, grouped
    by layer in the plan's *consumption* order."""

    by_layer: List[Tuple[int, List[Tuple[str, TransferHandle]]]]

    def wait_all(self) -> Dict[str, jax.Array]:
        """Retire every handle in consumption order (layer by layer)."""
        out: Dict[str, jax.Array] = {}
        for _, pairs in self.by_layer:
            for key, h in pairs:
                out[key] = h.wait()
        return out


@dataclass
class PrefetchStats:
    steps: int = 0
    fetches_issued: int = 0
    plan_leads: Dict[int, int] = field(default_factory=dict)

    @property
    def mean_plan_lead(self) -> float:
        """Mean number of plan slots between a layer's prefetch and its
        consuming compute node in the refined order (>0 ⇒ fetches are
        scheduled ahead of their consumers)."""
        if not self.plan_leads:
            return 0.0
        return sum(self.plan_leads.values()) / len(self.plan_leads)

    @property
    def mean_fetches_per_step(self) -> float:
        """Observed per-step fetch fan-out — the ``pages_per_step`` input
        to the calibration loop's in-flight sizing."""
        return self.fetches_issued / self.steps if self.steps else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"steps": self.steps, "fetches_issued": self.fetches_issued,
                "layers_planned": len(self.plan_leads),
                "mean_plan_lead": self.mean_plan_lead}


class PlanPrefetcher:
    def __init__(self, cfg: ModelConfig, batch: int, max_seq: int, *,
                 pool: MemoryPoolManager, hw: HardwareSpec = TPU_V5E,
                 refine: bool = True,
                 insert_opts: Optional[InsertionOptions] = None,
                 plan_cache: Optional[Dict[Any, OffloadPlan]] = None,
                 tracer=None) -> None:
        self.pool = pool
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # insertion options come from the session/config; the fallback is
        # the documented paged default (min_bytes=1 — the mandatory prefetch
        # of every pool-resident KV tensor must be planned even for
        # smoke-scale models)
        opts = insert_opts if insert_opts is not None else PAGED_INSERTION
        # the pool's tier topology joins the key: plans computed under
        # different hierarchies (or a calibrated vs static hw, via hw.name)
        # must never alias
        key = ("decode_plan", cfg.name, batch, max_seq, refine, hw.name, opts,
               getattr(pool, "topology", None))
        if plan_cache is not None and key in plan_cache:
            self.plan = plan_cache[key]
        else:
            g = trace_decode_step(cfg, batch, max_seq,
                                  TraceOptions(remote_kv=True))
            planner = HyperOffloadPlanner(hw, insert_opts=opts)
            self.plan = planner.plan(g, refine=refine)
            if plan_cache is not None:
                plan_cache[key] = self.plan
        pos = {n: i for i, n in enumerate(self.plan.order)}
        # issue schedule: layer index of each prefetch::kv_i, in plan order
        self.issue_order: List[int] = []
        consume_pos: Dict[int, int] = {}
        issue_pos: Dict[int, int] = {}
        for name in self.plan.order:
            node = self.plan.graph.nodes[name]
            if node.kind == "prefetch" and node.tensor.startswith("kv_"):
                layer = int(node.tensor.split("_", 1)[1])
                self.issue_order.append(layer)
                issue_pos[layer] = pos[name]
            elif node.kind == "compute" and name.startswith("dec_"):
                consume_pos[int(name.split("_", 1)[1])] = pos[name]
        self.consumption_order: List[int] = sorted(
            consume_pos, key=consume_pos.get)
        self.stats = PrefetchStats(plan_leads={
            l: consume_pos[l] - issue_pos[l]
            for l in issue_pos if l in consume_pos})

    @property
    def planned_layers(self) -> Sequence[int]:
        return tuple(self.issue_order)

    def issue(self, keys_by_layer: Mapping[int, Sequence[str]]) -> InFlightFetches:
        """Issue one step's page fetches in the refined plan order (layers
        whose pages the caller didn't name are skipped — e.g. empty slots).
        Returns the in-flight handles grouped in consumption order."""
        issued: Dict[int, List[Tuple[str, TransferHandle]]] = {}
        t0 = self.tracer.now() if self.tracer.enabled else 0.0
        for layer in self.issue_order:
            pairs = [(k, self.pool.prefetch(k))
                     for k in keys_by_layer.get(layer, ())]
            if pairs:
                issued[layer] = pairs
                self.stats.fetches_issued += len(pairs)
        self.stats.steps += 1
        if self.tracer.enabled:
            self.tracer.complete(
                "sched", "prefetch_issue", t0, self.tracer.now() - t0,
                {"fetches": sum(len(p) for p in issued.values()),
                 "layers": len(issued)})
        by_layer = [(l, issued[l]) for l in self.consumption_order if l in issued]
        return InFlightFetches(by_layer=by_layer)
