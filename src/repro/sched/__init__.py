"""Continuous-batching serving scheduler with plan-driven KV prefetch.

- ``requests``  — ``Request``/``RequestState`` lifecycle (QUEUED → PREFILL
  → DECODE → DONE) with per-request ``KVPageTable`` page tables;
- ``queue``     — arrival queue + pool-capacity-aware admission control
  (device+host tiers must hold a request's worst-case pages);
- ``scheduler`` — the step loop: joins/retires sequences every decode step,
  interleaves prefill with decode, parks cold sequences' pages through the
  pool's priority+LRU manager;
- ``prefetch``  — plan-driven prefetcher running ``HyperOffloadPlanner``'s
  refined decode order at serving time: layer *l+1*'s page fetches issue
  while layer *l*'s are consumed.
"""

from repro.sched.prefetch import InFlightFetches, PlanPrefetcher, PrefetchStats
from repro.sched.queue import AdmissionController, ArrivalQueue, poisson_trace
from repro.sched.requests import (
    DECODE, DONE, PREEMPTED, PREFILL, QUEUED, SHED, Request, RequestState,
)
from repro.sched.scheduler import (
    ContinuousScheduler, SchedStats, SchedulerConfig,
)

__all__ = [
    "QUEUED", "PREFILL", "DECODE", "DONE", "PREEMPTED", "SHED",
    "Request", "RequestState",
    "ArrivalQueue", "AdmissionController", "poisson_trace",
    "PlanPrefetcher", "PrefetchStats", "InFlightFetches",
    "ContinuousScheduler", "SchedulerConfig", "SchedStats",
]
