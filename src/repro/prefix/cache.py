"""Cross-request prefix KV cache over the memory-pool tiers.

``PrefixCacheManager`` makes shared prompt prefixes first-class,
ref-counted pool citizens: each cached page (``page_size`` tokens × one
KV slice per layer leaf) is a ``MemoryPoolManager`` entry, indexed by the
token-id radix tree in ``prefix.index``. The serving scheduler consults it
at admission (``lookup`` — a hit maps the shared pages into the request's
row cache and prefill starts at the match offset) and feeds it at
retirement (``donate`` — the retired prompt's full prefix pages enter the
cache instead of being freed).

Sharing is **copy-on-write by construction**: a hit *copies* the shared
page contents into the admitted request's own row cache, and the request
parks/overwrites only its own copies from then on — the cached entries are
never written after donation, so any number of concurrent readers share
one physical page per tier.

Tiering and lifetime follow the pool's priority+LRU manager:

- cached pages are stored device-resident at priority 0.0 — *below* any
  live request's parked pages, so under device pressure prefix pages age
  down to host before request state does, and LRU keeps the *hot*
  prefixes (recently matched — every hit refreshes the pool LRU clock via
  the fetch) device-resident while cold ones spill;
- while a page is ref'd by a running request its entries are **pinned**
  (the pool's victim scan skips them), so eviction can never pull a page
  out from under a reader; the pins drop on the final ``release``;
- ``pin_tier`` is the residency floor: a page the pool spills *below* it
  (e.g. host → remote with the default ``pin_tier="host"``) is deemed
  cheaper to recompute than to fetch back, and the eviction listener
  **invalidates** it — the node and every deeper node (a longer prefix is
  meaningless without one of its pages) leave the index and the pool;
- ``max_pages`` bounds the cache's own footprint: donations beyond it
  evict the coldest unref'd leaf pages first, and are rejected outright
  when everything is ref'd.

The manager is layout-agnostic: pages are opaque ``label -> array`` dicts
(the scheduler uses its ``L{layer}.{leaf}`` page labels), so nothing here
depends on model internals.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional

import numpy as np

from repro.obs import NULL_TRACER
from repro.pool import DEVICE_TIER, HOST_TIER
from repro.pool.manager import MemoryPoolManager, PoolCapacityError, PoolEntry
from repro.prefix.index import PrefixNode, RadixPrefixIndex

_PREFIX_IDS = itertools.count()

#: priority of cached prefix pages in the pool: below any live request's
#: parked pages (priority >= 1.0), so prefix pages age down first and a
#: running request's state is never displaced by a cache optimization.
PREFIX_PAGE_PRIORITY = 0.0


@dataclass
class PrefixCacheStats:
    hits: int = 0
    misses: int = 0
    hit_pages: int = 0
    hit_tokens: int = 0            # prefill tokens served from cache
    donations: int = 0
    donated_pages: int = 0
    rejected_donations: int = 0    # budget full of ref'd pages
    evictions: int = 0             # pages dropped by the max_pages budget
    invalidations: int = 0         # pages dropped by the pin_tier floor
    releases: int = 0

    def snapshot(self) -> Dict[str, float]:
        return dict(self.__dict__)


@dataclass
class PrefixHit:
    """One admission-time match: the chain of shared pages a request reads
    (refs held until ``PrefixCacheManager.release``)."""

    nodes: List[PrefixNode]
    page_size: int
    released: bool = field(default=False, repr=False)

    @property
    def n_pages(self) -> int:
        return len(self.nodes)

    @property
    def tokens(self) -> int:
        """Prompt tokens covered — where suffix prefill starts."""
        return len(self.nodes) * self.page_size

    def page_keys(self) -> List[Dict[str, str]]:
        """Per matched page (shallowest first): page label -> pool key."""
        return [dict(n.entries) for n in self.nodes]


class PrefixCacheManager:
    """Radix-indexed, ref-counted prefix-KV page cache (see module doc).

    Single-threaded by design, like the scheduler that drives it; the only
    reentrant path is the pool's eviction listener, which the pool calls
    under its own (reentrant) lock.
    """

    def __init__(self, pool: MemoryPoolManager, *, page_size: int,
                 max_pages: Optional[int] = None, min_match_pages: int = 1,
                 pin_tier: str = HOST_TIER, tracer=None) -> None:
        if max_pages is not None and max_pages < 1:
            raise ValueError("max_pages must be >= 1 (or None = unbounded)")
        if min_match_pages < 1:
            raise ValueError("min_match_pages must be >= 1")
        if pin_tier not in pool.spill_order:
            raise ValueError(f"pin_tier {pin_tier!r} not in pool tiers "
                             f"{pool.spill_order}")
        self.pool = pool
        self.page_size = page_size
        self.max_pages = max_pages
        self.min_match_pages = min_match_pages
        self.pin_tier = pin_tier
        self.index = RadixPrefixIndex(page_size)
        self.stats = PrefixCacheStats()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._ns = f"pfx{next(_PREFIX_IDS)}"
        self._owner: Dict[str, PrefixNode] = {}   # pool key -> owning node
        self._floor = pool.spill_order.index(pin_tier)
        # pool keys invalidated from inside the evict listener; dropped at
        # the next manager call (see _on_evict)
        self._deferred_drops: List[str] = []
        pool.add_evict_listener(self._on_evict)
        self._closed = False

    # -- observability -------------------------------------------------
    def __len__(self) -> int:
        """Cached pages (== index nodes)."""
        return len(self.index)

    @property
    def live_refs(self) -> int:
        return sum(n.refs for n in self.index.nodes.values())

    def snapshot(self) -> Dict[str, float]:
        out = self.stats.snapshot()
        out["pages"] = len(self.index)
        out["refs"] = self.live_refs
        out["pinned_pages"] = sum(
            1 for n in self.index.nodes.values() if n.refs > 0)
        return out

    # -- admission-side ------------------------------------------------
    def lookup(self, tokens: np.ndarray, *,
               max_tokens: Optional[int] = None) -> Optional[PrefixHit]:
        """Match ``tokens`` against the cached prefixes and take a read
        ref on every matched page (pinning it against eviction) until the
        caller ``release``s the hit. ``max_tokens`` caps the match — the
        scheduler passes ``prompt_len - 1`` so at least one real token
        remains to prefill (the first sampled token needs its logits).
        Returns None on a miss (or a match shorter than
        ``min_match_pages``)."""
        self._flush_deferred()
        max_pages = None if max_tokens is None else max_tokens // self.page_size
        chain = self.index.match(tokens, max_pages)
        if len(chain) < self.min_match_pages:
            self.stats.misses += 1
            if self.tracer.enabled:
                self.tracer.instant("prefix", "lookup",
                                    {"hit": False, "pages": 0})
            return None
        for node in chain:
            node.refs += 1
            node.hits += 1
            if node.refs == 1:
                for key in node.entries.values():
                    self.pool.pin(key, True)
        self.stats.hits += 1
        self.stats.hit_pages += len(chain)
        self.stats.hit_tokens += len(chain) * self.page_size
        if self.tracer.enabled:
            self.tracer.instant(
                "prefix", "lookup",
                {"hit": True, "pages": len(chain),
                 "tokens": len(chain) * self.page_size})
        return PrefixHit(nodes=chain, page_size=self.page_size)

    def release(self, hit: PrefixHit) -> None:
        """Drop the hit's read refs (idempotent); a page's entries unpin —
        becoming evictable again — only on the *final* release."""
        if hit.released:
            return
        hit.released = True
        self._flush_deferred()
        self.stats.releases += 1
        for node in hit.nodes:
            node.refs -= 1
            if node.refs == 0 and node.node_id in self.index.nodes:
                for key in node.entries.values():
                    if key in self.pool:
                        self.pool.pin(key, False)

    # -- retirement-side -----------------------------------------------
    def donate(self, tokens: np.ndarray, n_pages: int,
               extract: Callable[[int], Mapping[str, np.ndarray]]) -> int:
        """Insert the first ``n_pages`` pages of a retired prompt.
        ``extract(page_idx)`` supplies ``label -> KV slice`` for one page
        and is called **only for pages not already cached** (re-donating a
        popular prefix is a pure LRU refresh). Returns the number of pages
        actually added; pages that don't fit under ``max_pages`` after
        evicting every unref'd cold page are rejected."""
        if n_pages < 1:
            return 0
        self._flush_deferred()
        chain, created = self.index.insert(tokens, n_pages)
        if not created:
            return 0
        self.stats.donations += 1
        added = 0
        for node in created:
            if node.node_id not in self.index.nodes:
                # detached when a shallower page of this same donation was
                # rejected (a chain is only as valid as its shallowest page)
                self.stats.rejected_donations += 1
                continue
            if not self._make_budget_room(node):
                self._discard(node)
                self.stats.rejected_donations += 1
                continue
            try:
                for label, value in extract(node.depth - 1).items():
                    key = f"{self._ns}/n{node.node_id}/{label}"
                    self.pool.put(key, value, self.pool.top_tier,
                                  priority=PREFIX_PAGE_PRIORITY)
                    node.entries[label] = key
                    self._owner[key] = node
            except PoolCapacityError:
                # every tier full of unevictable data — undo this node
                self._drop_node_entries(node)
                self._discard(node)
                self.stats.rejected_donations += 1
                continue
            if node.node_id not in self.index.nodes:
                # a spill cascade triggered by this donation's own puts
                # invalidated the node mid-store — undo what's left of it
                self._drop_node_entries(node)
                self.stats.rejected_donations += 1
                continue
            added += 1
            self.stats.donated_pages += 1
        self._flush_deferred()
        if self.tracer.enabled:
            self.tracer.instant("prefix", "donate",
                                {"pages": added, "offered": n_pages})
        return added

    # -- internals -----------------------------------------------------
    def _discard(self, node: PrefixNode) -> None:
        """Remove a node that never became (or no longer is) a valid cache
        page. Descendants created in the same donation are handled by
        their own loop iteration (a parentless node rejects its subtree:
        removing it detaches them from the index)."""
        for n in self.index.remove(node):
            self._drop_node_entries(n)

    def _drop_node_entries(self, node: PrefixNode) -> None:
        for key in node.entries.values():
            self._owner.pop(key, None)
            if key in self.pool:
                self.pool.drop(key)
        node.entries.clear()

    def _make_budget_room(self, node: PrefixNode) -> bool:
        """Evict coldest unref'd leaf pages until the index (which already
        counts ``node`` — ``insert`` adds created nodes up front) fits
        under ``max_pages``. ``node`` itself is never a victim, and its
        ancestors are interior while it lives, so the chain being donated
        is safe. False if the budget is full of ref'd/interior pages."""
        if self.max_pages is None:
            return True
        while len(self.index) > self.max_pages:
            victims = [v for v in self.index.evictable() if v is not node]
            if not victims:
                return False
            self._discard(victims[0])
            self.stats.evictions += 1
        return True

    def _on_evict(self, entry: PoolEntry, dst: str) -> None:
        """Pool spill listener: a page falling *below* the ``pin_tier``
        floor is invalidated — pruned from the index (with every deeper
        page of its chain) immediately, but its pool entries are only
        *queued* for dropping. The listener runs inside the pool's
        eviction path, and a chain invalidation can name an entry that is
        itself mid-eviction further up the stack (the victim whose spill
        cascaded into this one) — dropping it here would corrupt the tier
        accounting when its eviction frame resumes. The queued keys are
        dropped at the next manager call (``_flush_deferred``)."""
        node = self._owner.get(entry.key)
        if node is None:
            return
        if self.pool.spill_order.index(dst) <= self._floor:
            return   # still at/above the floor: cold but valid
        removed = self.index.remove(node)
        for n in removed:
            for key in n.entries.values():
                self._owner.pop(key, None)
                self._deferred_drops.append(key)
            n.entries.clear()
        self.stats.invalidations += len(removed)
        if self.tracer.enabled:
            self.tracer.instant("prefix", "invalidate",
                                {"pages": len(removed), "below": dst})

    def _flush_deferred(self) -> None:
        while self._deferred_drops:
            key = self._deferred_drops.pop()
            if key in self.pool:
                self.pool.drop(key)

    # -- lifecycle -----------------------------------------------------
    def close(self) -> None:
        """Unhook from the (possibly shared) pool and drop every cached
        page. Idempotent."""
        if self._closed:
            return
        self._closed = True
        self.pool.remove_evict_listener(self._on_evict)
        self._flush_deferred()
        for node in list(self.index.nodes.values()):
            self._drop_node_entries(node)
        self.index = RadixPrefixIndex(self.page_size)
        self._owner.clear()
