"""Cross-request prefix KV cache: radix-indexed, ref-counted,
copy-on-write page sharing over the memory-pool tiers."""

from repro.prefix.cache import (
    PREFIX_PAGE_PRIORITY, PrefixCacheManager, PrefixCacheStats, PrefixHit,
)
from repro.prefix.index import PrefixNode, RadixPrefixIndex

__all__ = [
    "PREFIX_PAGE_PRIORITY",
    "PrefixCacheManager",
    "PrefixCacheStats",
    "PrefixHit",
    "PrefixNode",
    "RadixPrefixIndex",
]
