"""Token-id radix index over page-granular prompt prefixes.

The index answers one question at admission time: *how many leading pages
of this prompt have we already computed KV for?* Keys are pages — fixed
``page_size`` runs of token ids — so two prompts share a cache node iff
they agree on a whole page, and a lookup walks at most
``prompt_len // page_size`` dict hops. Each node represents one page and
owns (via ``entries``, maintained by ``cache.PrefixCacheManager``) the
pool keys of that page's KV slices; a chain of nodes from the root is a
cached prefix.

The tree is pure bookkeeping — no tensors, no pool access — so it can be
unit-tested and reasoned about independently of the memory subsystem:

- ``match(tokens)``    — longest chain of cached pages leading the prompt;
- ``insert(tokens, n)``— extend the tree to cover the first ``n`` pages,
  returning the full chain and which nodes are new (donation fills those);
- ``remove(node)``     — drop a node *and every descendant* (a longer
  prefix is meaningless once one of its pages is gone);
- ``refs``/``last_use``— per-node pin count and LRU clock for the
  manager's eviction policy.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

_NODE_IDS = itertools.count()


class PrefixNode:
    """One cached page: ``page_size`` token ids at depth*page_size offset."""

    __slots__ = ("node_id", "parent", "page_key", "children", "entries",
                 "refs", "last_use", "depth", "hits")

    def __init__(self, parent: Optional["PrefixNode"], page_key: bytes,
                 depth: int) -> None:
        self.node_id = next(_NODE_IDS)
        self.parent = parent
        self.page_key = page_key
        self.children: Dict[bytes, "PrefixNode"] = {}
        self.entries: Dict[str, str] = {}   # page label -> pool key
        self.refs = 0                       # live requests reading this page
        self.last_use = 0                   # index LRU clock
        self.depth = depth                  # pages from root (1-based)
        self.hits = 0

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        return (f"PrefixNode(id={self.node_id}, depth={self.depth}, "
                f"refs={self.refs}, children={len(self.children)})")


class RadixPrefixIndex:
    """Radix tree over token pages; one node per cached page."""

    def __init__(self, page_size: int) -> None:
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self.root = PrefixNode(None, b"", 0)
        self.nodes: Dict[int, PrefixNode] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self.nodes)

    def _page_key(self, tokens: np.ndarray, page: int) -> bytes:
        a = page * self.page_size
        return np.ascontiguousarray(
            tokens[a:a + self.page_size], dtype=np.int32).tobytes()

    def _touch(self, chain: List[PrefixNode]) -> None:
        self._clock += 1
        for node in chain:
            node.last_use = self._clock

    # -- lookup --------------------------------------------------------
    def match(self, tokens: np.ndarray,
              max_pages: Optional[int] = None) -> List[PrefixNode]:
        """Longest cached chain of whole pages leading ``tokens`` (root →
        deepest), at most ``max_pages`` long. Refreshes the chain's LRU
        clock — a match is a use."""
        tokens = np.asarray(tokens).reshape(-1)
        limit = len(tokens) // self.page_size
        if max_pages is not None:
            limit = min(limit, max_pages)
        chain: List[PrefixNode] = []
        node = self.root
        for p in range(limit):
            child = node.children.get(self._page_key(tokens, p))
            if child is None:
                break
            chain.append(child)
            node = child
        if chain:
            self._touch(chain)
        return chain

    # -- growth --------------------------------------------------------
    def insert(self, tokens: np.ndarray,
               n_pages: int) -> Tuple[List[PrefixNode], List[PrefixNode]]:
        """Extend the tree to cover the first ``n_pages`` pages of
        ``tokens``. Returns ``(chain, created)``: the full root→deep chain
        and the subset that did not exist before (whose KV the caller must
        supply)."""
        tokens = np.asarray(tokens).reshape(-1)
        if n_pages * self.page_size > len(tokens):
            raise ValueError(
                f"prompt of {len(tokens)} tokens has no {n_pages} full "
                f"pages of {self.page_size}")
        chain: List[PrefixNode] = []
        created: List[PrefixNode] = []
        node = self.root
        for p in range(n_pages):
            key = self._page_key(tokens, p)
            child = node.children.get(key)
            if child is None:
                child = PrefixNode(node, key, p + 1)
                node.children[key] = child
                self.nodes[child.node_id] = child
                created.append(child)
            chain.append(child)
            node = child
        if chain:
            self._touch(chain)
        return chain, created

    # -- removal -------------------------------------------------------
    def remove(self, node: PrefixNode) -> List[PrefixNode]:
        """Detach ``node`` and its whole subtree (deepest prefixes first).
        Returns every removed node so the owner can release their pool
        entries. A chain is only as valid as its shallowest page."""
        if node.parent is not None:
            node.parent.children.pop(node.page_key, None)
        removed: List[PrefixNode] = []
        stack = [node]
        while stack:
            n = stack.pop()
            removed.append(n)
            self.nodes.pop(n.node_id, None)
            stack.extend(n.children.values())
            n.children.clear()
            n.parent = None
        return removed

    def evictable(self) -> List[PrefixNode]:
        """Leaf nodes with no live refs, coldest first — the only safe
        eviction order (removing an interior node would orphan deeper
        pages; removing a ref'd node would corrupt a running request)."""
        leaves = [n for n in self.nodes.values()
                  if not n.children and n.refs == 0]
        return sorted(leaves, key=lambda n: n.last_use)
