"""Divisibility-safe logical→physical sharding rules.

Model code annotates tensors with *logical* axis names ("batch", "embed",
"mlp", "heads", "kv_heads", "vocab", "seq", "experts", ...). A rule-set maps
each logical name to zero or more mesh axes. ``logical_spec`` resolves names
to a ``PartitionSpec``, silently dropping any mesh axis that does not evenly
divide the corresponding dimension — GQA kv=8 on a 16-way model axis, 40
experts on a 16-way axis, batch=1 decode, etc. all degrade gracefully to
replication instead of failing to lower.

This is the 2-D FSDP×TP scheme from DESIGN.md §6:
  - "fsdp"-ish sharding over the ``data`` axis (d_model / vocab rows)
  - tensor parallelism over the ``model`` axis (heads / d_ff / vocab cols)
  - batch over ``("pod", "data")`` when the pod axis exists
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
AxisRules = Dict[str, MeshAxes]

# Single-pod production mesh: ("data", "model") = (16, 16).
DEFAULT_RULES: AxisRules = {
    "batch": ("data",),
    "seq": None,
    "embed": ("data",),          # fsdp: shard the d_model rows of weights
    "embed_act": None,           # activations keep d_model replicated
    "seq_act": None,             # sequence parallelism: the residual stream's
                                 # seq dim shards over "model" at layer
                                 # boundaries when the launcher enables it
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_dim": ("model",),
    "kv_dim": ("model",),
    "vocab": ("model",),
    "experts": None,             # 40/8 experts do not divide 16; see DESIGN.md
    "expert_mlp": ("model",),
    "cache_seq": None,
    "cache_batch": ("data",),
    "cache_heads": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": None,
    "layers": None,
    "lora": None,
    "frames": None,
}

# Two-pod mesh: ("pod", "data", "model") — batch additionally over pods,
# weights replicated across pods (data-parallel pods).
MULTIPOD_RULES: AxisRules = dict(
    DEFAULT_RULES,
    batch=("pod", "data"),
    cache_batch=("pod", "data"),
)

_active_rules: contextvars.ContextVar[Optional[AxisRules]] = contextvars.ContextVar(
    "repro_axis_rules", default=None
)
_active_mesh: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None
)


@contextlib.contextmanager
def axis_rules(rules: AxisRules, mesh: Optional[Mesh] = None):
    """Activate a logical-axis rule-set (and optionally a mesh) for model code."""
    t1 = _active_rules.set(rules)
    t2 = _active_mesh.set(mesh)
    try:
        yield
    finally:
        _active_rules.reset(t1)
        _active_mesh.reset(t2)


def current_rules() -> Optional[AxisRules]:
    return _active_rules.get()


def current_mesh() -> Optional[Mesh]:
    return _active_mesh.get()


def _mesh_axis_size(mesh: Optional[Mesh], axes: Tuple[str, ...]) -> int:
    if mesh is None:
        return 1
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


def logical_spec(
    dims: Sequence[int],
    names: Sequence[Optional[str]],
    rules: Optional[AxisRules] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Resolve logical axis names for a shape to a PartitionSpec.

    Mesh axes that do not evenly divide the dimension are dropped. An axis
    already consumed by an earlier dimension is dropped too (PartitionSpec
    must not repeat mesh axes).
    """
    rules = rules if rules is not None else (current_rules() or {})
    mesh = mesh if mesh is not None else current_mesh()
    used = set()
    parts = []
    for dim, name in zip(dims, names):
        if name is None:
            parts.append(None)
            continue
        mapped = rules.get(name)
        if mapped is None:
            parts.append(None)
            continue
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
            continue
        if mesh is not None:
            # drop the whole mapping if it doesn't divide evenly
            size = _mesh_axis_size(mesh, axes)
            if size == 0 or dim % max(size, 1) != 0:
                # try progressively shorter prefixes
                while axes and dim % _mesh_axis_size(mesh, axes) != 0:
                    axes = axes[:-1]
                if not axes:
                    parts.append(None)
                    continue
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Apply a logical sharding constraint if a rule-set is active.

    Outside any ``axis_rules`` context (unit tests, single-device runs) this
    is the identity, so model code is unconditionally annotated.
    """
    rules = current_rules()
    if rules is None:
        return x
    mesh = current_mesh()
    spec = logical_spec(x.shape, names, rules, mesh)
    if all(p is None for p in spec):
        return x
    if mesh is not None:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, dims: Sequence[int], names: Sequence[Optional[str]],
                   rules: Optional[AxisRules] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(dims, names, rules, mesh))
