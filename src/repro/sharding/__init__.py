from repro.sharding.rules import (
    AxisRules,
    DEFAULT_RULES,
    MULTIPOD_RULES,
    axis_rules,
    constrain,
    current_rules,
    logical_spec,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "MULTIPOD_RULES",
    "axis_rules",
    "constrain",
    "current_rules",
    "logical_spec",
]
