"""Activation-offload rematerialization policies (§5.1 case 1).

The model substrate tags activations with ``checkpoint_name``:
"resid" (per-layer residual stream), "attn_out", "mlp_out". The offload
policy keeps the tagged values across fwd→bwd but parks them in
``pinned_host`` memory — XLA emits the device→host copy after the producer
and the host→device copy before the backward consumer, i.e. exactly the
Store/Prefetch pair HyperOffload's IR models, scheduled by XLA's
latency-hiding scheduler on real hardware.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.pool import backend as pool_backend

OFFLOADABLE_NAMES = ("resid", "attn_out", "mlp_out")


def remat_policy(name: str = "nothing"):
    """Plain (non-offloading) remat policies for the baseline."""
    if name == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    if name == "everything":
        return jax.checkpoint_policies.everything_saveable
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "save_resid":
        return jax.checkpoint_policies.save_only_these_names("resid")
    raise ValueError(name)


def offload_remat_policy(names: Sequence[str] = ("resid",),
                         offload_dst: Optional[str] = None):
    """Offload the named activations to host memory instead of keeping them
    in HBM or recomputing them. The destination defaults to the probed host
    memory kind (pinned_host on TPU/GPU, unpinned_host on XLA:CPU); on
    platforms with no host memory kind at all, degrade to saving the named
    activations on device — never raise."""
    if offload_dst is None:
        offload_dst = pool_backend.host_memory_kind()
        if offload_dst is None:
            return jax.checkpoint_policies.save_only_these_names(*names)
    return jax.checkpoint_policies.save_and_offload_only_these_names(
        names_which_can_be_saved=[],
        names_which_can_be_offloaded=list(names),
        offload_src="device",
        offload_dst=offload_dst,
    )
