"""Optimizer-state host offload via memory-kind shardings (§5.1 case 2).

Optimizer moments are touched once per step; HyperOffload parks them in the
remote pool between updates. In JAX this is a sharding whose
``memory_kind`` is ``pinned_host``: the train step receives host-resident
moments, XLA copies them in before the update and the new moments are
committed back to host by the output sharding — the Prefetch/Store pair at
the optimizer-update node of the IR trace.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec, SingleDeviceSharding


def _with_memory_kind(sharding, kind: str):
    if hasattr(sharding, "with_memory_kind"):
        return sharding.with_memory_kind(kind)
    raise TypeError(f"sharding {sharding} has no memory kinds")


def host_shardings(tree: Any, kind: str = "pinned_host") -> Any:
    """Map each array's current sharding to the host memory kind."""
    return jax.tree.map(
        lambda x: _with_memory_kind(x.sharding, kind), tree)


def host_offload_state(state: Any, kind: str = "pinned_host") -> Any:
    """Move a pytree of arrays to host memory (Store + Detach)."""
    return jax.tree.map(
        lambda x: jax.device_put(x, _with_memory_kind(x.sharding, kind)),
        state)


def device_fetch_state(state: Any, kind: str = "device") -> Any:
    """Bring a host-parked pytree back to device memory (Prefetch)."""
    return jax.tree.map(
        lambda x: jax.device_put(x, _with_memory_kind(x.sharding, kind)),
        state)


# -- in-jit variants ---------------------------------------------------------
# Inside a jitted step, abstract values carry a memory space but no concrete
# sharding to mutate; transfers use explicit target shardings instead.


def _default_shardings(kind: str):
    dev = jax.devices()[0]
    return SingleDeviceSharding(dev, memory_kind=kind)


def fetch_in_jit(state: Any, sharding=None) -> Any:
    """Prefetch a host-parked pytree inside a jitted computation."""
    s = sharding if sharding is not None else _default_shardings("device")
    return jax.tree.map(lambda x: jax.device_put(x, s), state)


def park_in_jit(state: Any, sharding=None) -> Any:
    """Store a pytree to host memory inside a jitted computation."""
    s = sharding if sharding is not None else _default_shardings("pinned_host")
    return jax.tree.map(lambda x: jax.device_put(x, s), state)
