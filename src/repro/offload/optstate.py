"""Optimizer-state host offload via memory-kind shardings (§5.1 case 2).

Optimizer moments are touched once per step; HyperOffload parks them in the
remote pool between updates. In JAX this is a sharding whose
``memory_kind`` is the platform's host kind: the train step receives
host-resident moments, XLA copies them in before the update and the new
moments are committed back to host by the output sharding — the
Prefetch/Store pair at the optimizer-update node of the IR trace.

The host kind is probed through ``repro.pool.backend`` rather than
hard-coded: ``pinned_host`` where addressable (TPU/GPU), ``unpinned_host``
on XLA:CPU, and a NumPy host buffer as the last-resort fallback on
platforms with no memory-kind support at all — offload never raises, it
degrades. A specific kind can be forced per setup via
``OffloadConfig.host_memory_kind`` (threaded through
``TrainStepConfig.host_kind`` by ``HyperOffloadSession.train_step``).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import SingleDeviceSharding

from repro.pool import backend as pool_backend


def _resolve_host_kind(kind: Optional[str]) -> Optional[str]:
    """Map a requested kind onto what this platform addresses."""
    caps = pool_backend.capabilities()
    if kind is not None and kind in caps.memory_kinds:
        return kind
    return caps.host_kind


def _with_memory_kind(sharding, kind: str):
    if hasattr(sharding, "with_memory_kind"):
        return sharding.with_memory_kind(kind)
    raise TypeError(f"sharding {sharding} has no memory kinds")


def host_shardings(tree: Any, kind: Optional[str] = None) -> Any:
    """Map each array's current sharding to the host memory kind."""
    k = _resolve_host_kind(kind)
    if k is None:
        raise ValueError("platform addresses no host memory kind; "
                         "use host_offload_state (NumPy fallback)")
    return jax.tree.map(lambda x: _with_memory_kind(x.sharding, k), tree)


def host_offload_state(state: Any, kind: Optional[str] = None) -> Any:
    """Move a pytree of arrays to host memory (Store + Detach). Falls back
    to NumPy host buffers where memory-kind shardings are unsupported."""
    k = _resolve_host_kind(kind)
    if k is None:
        return jax.tree.map(pool_backend.to_host, state)
    return jax.tree.map(
        lambda x: jax.device_put(x, _with_memory_kind(x.sharding, k))
        if hasattr(x, "sharding") else pool_backend.to_host(x),
        state)


def device_fetch_state(state: Any, kind: Optional[str] = None) -> Any:
    """Bring a host-parked pytree back to device memory (Prefetch). Each
    leaf keeps its own sharding (only the memory kind changes), so
    multi-device trees come back with their original distribution."""
    caps = pool_backend.capabilities()
    if kind is not None and kind in caps.memory_kinds:
        k = kind
    else:
        k = caps.default_kind   # the device memory, however it's spelled

    def fetch(x):
        if isinstance(x, np.ndarray) or not hasattr(x, "sharding"):
            return pool_backend.to_device(x)
        if k is not None and hasattr(x.sharding, "with_memory_kind"):
            return jax.device_put(x, _with_memory_kind(x.sharding, k))
        return jax.device_put(x, pool_backend.device_sharding())

    return jax.tree.map(fetch, state)


# -- in-jit variants ---------------------------------------------------------
# Inside a jitted step, abstract values carry a memory space but no concrete
# sharding to mutate; transfers use explicit target shardings instead.


def _default_shardings(kind: Optional[str]):
    dev = jax.devices()[0]
    if kind is None:
        return SingleDeviceSharding(dev)
    return SingleDeviceSharding(dev, memory_kind=kind)


def fetch_in_jit(state: Any, sharding=None) -> Any:
    """Prefetch a host-parked pytree inside a jitted computation."""
    s = sharding if sharding is not None else _default_shardings(None)
    return jax.tree.map(lambda x: jax.device_put(x, s), state)


def park_in_jit(state: Any, sharding=None) -> Any:
    """Store a pytree to host memory inside a jitted computation."""
    s = (sharding if sharding is not None
         else _default_shardings(pool_backend.host_memory_kind()))
    return jax.tree.map(lambda x: jax.device_put(x, s), state)
