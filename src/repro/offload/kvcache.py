"""Paged KV cache with a host-side (remote-pool) page store (§5.2).

Layout per layer: each full page is its own buffer in the pool
(``pinned_host`` memory — pages are non-contiguous by construction, exactly
like a paged allocator); the device keeps (a) a small *tail* buffer
accumulating the current partial page and (b) per-page key *summaries*
(mean key per page) used for sparse block selection — the paper's
DeepSeek+NSA inference setting, where only the top-k relevant KV blocks are
reloaded per decode step instead of the whole cache.

Decode attention runs in two segments — selected pool pages + device tail —
merged in a single softmax, so selecting *all* pages reproduces dense
attention against the oracle (tests/test_offload_runtime.py).

The page fetch (``jax.device_put`` of host pages) is the Prefetch cache
operator; the page flush on tail overflow is the Store. The serving engine
can issue next-layer fetches while the current layer computes, matching
the graph-driven overlap the compiler plans.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.3819763e38


def _host_sharding():
    d = jax.devices()[0]
    return jax.sharding.SingleDeviceSharding(d, memory_kind="pinned_host")


def _dev_sharding():
    return jax.sharding.SingleDeviceSharding(jax.devices()[0])


@jax.jit
def _page_summary(k_page: jax.Array) -> jax.Array:
    """(B, page, Hkv, D) -> (B, Hkv, D) mean key."""
    return jnp.mean(k_page, axis=1)


@dataclasses.dataclass
class PagedKVCache:
    """One attention layer's paged cache. ``n_layers`` instances make a model."""

    page_size: int
    n_pages: int               # pool capacity in pages
    batch: int
    n_kv_heads: int
    head_dim: int
    dtype: jnp.dtype

    k_pool: List[Optional[jax.Array]]   # per page: (B, page, Hkv, D) pinned_host
    v_pool: List[Optional[jax.Array]]
    k_summary: jax.Array       # (n_pages, B, Hkv, D) — device
    k_tail: jax.Array          # (B, page, Hkv, D) — device (partial page)
    v_tail: jax.Array
    length: int = 0            # tokens appended so far
    fetches: int = 0           # pool→device page transfers (stats)
    flushes: int = 0           # device→pool page stores

    # ------------------------------------------------------------------
    @classmethod
    def create(cls, *, batch: int, max_seq: int, page_size: int,
               n_kv_heads: int, head_dim: int, dtype=jnp.float32) -> "PagedKVCache":
        n_pages = -(-max_seq // page_size)
        return cls(
            page_size=page_size, n_pages=n_pages, batch=batch,
            n_kv_heads=n_kv_heads, head_dim=head_dim, dtype=dtype,
            k_pool=[None] * n_pages, v_pool=[None] * n_pages,
            k_summary=jnp.zeros((n_pages, batch, n_kv_heads, head_dim), dtype),
            k_tail=jnp.zeros((batch, page_size, n_kv_heads, head_dim), dtype),
            v_tail=jnp.zeros((batch, page_size, n_kv_heads, head_dim), dtype),
        )

    @property
    def full_pages(self) -> int:
        return self.length // self.page_size

    @property
    def tail_len(self) -> int:
        return self.length % self.page_size

    # ------------------------------------------------------------------
    def _flush_tail(self) -> None:
        """Store: commit the full tail page to the pool + update summary."""
        page_idx = self.length // self.page_size - 1
        host = _host_sharding()
        self.k_pool[page_idx] = jax.device_put(self.k_tail, host)
        self.v_pool[page_idx] = jax.device_put(self.v_tail, host)
        self.k_summary = self.k_summary.at[page_idx].set(
            _page_summary(self.k_tail))
        self.flushes += 1

    def append(self, k_t: jax.Array, v_t: jax.Array) -> None:
        """Append one token's K/V: (B, Hkv, D)."""
        i = self.tail_len
        self.k_tail = self.k_tail.at[:, i].set(k_t.astype(self.dtype))
        self.v_tail = self.v_tail.at[:, i].set(v_t.astype(self.dtype))
        self.length += 1
        if self.length % self.page_size == 0:
            self._flush_tail()

    def prefill(self, k_seq: jax.Array, v_seq: jax.Array) -> None:
        """Bulk-append a prompt: (B, S, Hkv, D)."""
        s = k_seq.shape[1]
        host = _host_sharding()
        n_full = s // self.page_size
        for pi in range(n_full):
            sl = slice(pi * self.page_size, (pi + 1) * self.page_size)
            kp = k_seq[:, sl].astype(self.dtype)
            vp = v_seq[:, sl].astype(self.dtype)
            self.k_pool[pi] = jax.device_put(kp, host)
            self.v_pool[pi] = jax.device_put(vp, host)
            self.k_summary = self.k_summary.at[pi].set(_page_summary(kp))
            self.flushes += 1
        rem = s - n_full * self.page_size
        if rem:
            self.k_tail = self.k_tail.at[:, :rem].set(
                k_seq[:, n_full * self.page_size:].astype(self.dtype))
            self.v_tail = self.v_tail.at[:, :rem].set(
                v_seq[:, n_full * self.page_size:].astype(self.dtype))
        self.length = s

    # ------------------------------------------------------------------
    def select_pages(self, q: jax.Array, top_k: Optional[int]) -> np.ndarray:
        """Sparse block selection: rank full pages by mean-key relevance to
        the query (B, Hq, D) → sorted page indices (host ints)."""
        n = self.full_pages
        if n == 0:
            return np.zeros((0,), np.int64)
        if top_k is None or top_k >= n:
            return np.arange(n)
        summ = self.k_summary[:n]                     # (n, B, Hkv, D)
        qm = jnp.mean(q.astype(jnp.float32), axis=(0, 1))   # (D,)
        scores = jnp.einsum("nbhd,d->n", summ.astype(jnp.float32), qm)
        idx = np.asarray(jax.lax.top_k(scores, top_k)[1])
        return np.sort(idx)

    def fetch_pages(self, idx: np.ndarray) -> Tuple[jax.Array, jax.Array]:
        """Prefetch: copy the selected pool pages to device memory. Returns
        (n_sel, B, page, Hkv, D) device arrays."""
        dev = _dev_sharding()
        if len(idx) == 0:
            shape = (0, self.batch, self.page_size, self.n_kv_heads, self.head_dim)
            return jnp.zeros(shape, self.dtype), jnp.zeros(shape, self.dtype)
        ks = [jax.device_put(self.k_pool[int(i)], dev) for i in idx]
        vs = [jax.device_put(self.v_pool[int(i)], dev) for i in idx]
        self.fetches += len(idx)
        return jnp.stack(ks), jnp.stack(vs)

    # ------------------------------------------------------------------
    def attend(self, q: jax.Array, *, scale: float,
               top_k_pages: Optional[int] = None,
               prefetched: Optional[Tuple[jax.Array, jax.Array, np.ndarray]] = None,
               ) -> jax.Array:
        """Decode attention of q (B, Hq, D) over selected pages + tail.
        ``prefetched`` lets the engine overlap next-layer fetches."""
        if prefetched is not None:
            kp, vp, idx = prefetched
        else:
            idx = self.select_pages(q, top_k_pages)
            kp, vp = self.fetch_pages(idx)
        return _paged_attend(q, kp, vp, self.k_tail, self.v_tail,
                             jnp.int32(self.tail_len), scale)


@jax.jit
def _segment_scores(q, k, scale):
    """q (B,Hq,D), k (B,T,Hkv,D) -> scores (B,Hq,T) in f32 (GQA aware)."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    return jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)).reshape(
        b, hq, k.shape[1])


@jax.jit
def _paged_attend(q, k_pages, v_pages, k_tail, v_tail, tail_len, scale):
    """Exact attention over [pages ++ tail] in one merged softmax."""
    b, hq, d = q.shape
    n, _, page, hkv, _ = k_pages.shape
    k_flat = k_pages.transpose(1, 0, 2, 3, 4).reshape(b, n * page, hkv, d)
    v_flat = v_pages.transpose(1, 0, 2, 3, 4).reshape(b, n * page, hkv, d)
    s_pages = _segment_scores(q, k_flat, scale)              # (B,Hq,n*page)
    s_tail = _segment_scores(q, k_tail, scale)               # (B,Hq,page)
    t_mask = jnp.arange(k_tail.shape[1]) < tail_len
    s_tail = jnp.where(t_mask[None, None, :], s_tail, NEG_INF)
    s = jnp.concatenate([s_pages, s_tail], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    v_all = jnp.concatenate([v_flat, v_tail], axis=1)        # (B,T,Hkv,D)
    g = hq // hkv
    pf = p.reshape(b, hkv, g, -1)
    out = jnp.einsum("bkgt,btkd->bkgd", pf, v_all.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
