"""Paged KV cache backed by the runtime memory pool (§5.2).

Layout per layer: each full page is its own entry in the
``MemoryPoolManager`` (host tier — pages are non-contiguous by
construction, exactly like a paged allocator); the device keeps (a) a small
*tail* buffer accumulating the current partial page and (b) per-page key
*summaries* (mean key per page) used for sparse block selection — the
paper's DeepSeek+NSA inference setting, where only the top-k relevant KV
blocks are reloaded per decode step instead of the whole cache.

Decode attention runs in two segments — selected pool pages + device tail —
merged in a single softmax, so selecting *all* pages reproduces dense
attention against the oracle (tests/test_offload_runtime.py).

The page fetch is the Prefetch cache operator (sync via ``pool.get`` or
async via ``prefetch_pages``/``TransferEngine``, which is how the serving
engine overlaps next-layer fetches with the current layer's compute); the
page flush on tail overflow is the Store. Capacity accounting and
host-kind probing live in the pool — on platforms where ``pinned_host``
shardings raise, pages degrade to ``unpinned_host`` or NumPy host buffers
without the cache noticing.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import paged_decode_attention_ref
from repro.pool import MemoryPoolManager, TransferHandle, auto_depth

NEG_INF = -2.3819763e38

#: jitted exact-math fused attend (the lowering-free serving path);
#: retraces only when the page-table *length* changes — once per flushed
#: page — never per step
_fused_attend_ref = functools.partial(
    jax.jit, static_argnames=("scale", "logit_cap"))(
        paged_decode_attention_ref)

# per-instance pool-key namespace, so caches sharing one pool (e.g. one pool
# across a model's layers) never collide on page keys
_CACHE_IDS = itertools.count()


class KVPageTable:
    """One request's KV pages in the pool — the serving scheduler's
    per-request page table (``sched.requests``).

    Each page is one (layer, leaf) row of the request's slice of the
    stacked decode cache, stored under a request-stable key: re-parking a
    page replaces the entry in place (no key churn), and the pool's
    priority+LRU manager decides *where* it lives — pages are parked hot
    (device tier, priority = recency), and under capacity pressure cold
    sequences' pages spill to the host tier, then to remote, without the
    table noticing. Capacity admission for the table happens up front via
    ``MemoryPoolManager.reserve`` (see ``sched.queue``), sized by
    ``worst_case_page_bytes`` — pages the request has not produced yet are
    charged at their full worst case.
    """

    def __init__(self, pool: MemoryPoolManager, name: str) -> None:
        self.pool = pool
        self.key_ns = f"{name}-{next(_CACHE_IDS)}"
        self.keys: dict = {}       # page label -> pool key
        self.parks: int = 0

    def __len__(self) -> int:
        return len(self.keys)

    def key_of(self, label: str) -> str:
        return self.keys.setdefault(label, f"{self.key_ns}/{label}")

    def park(self, label: str, value: jax.Array, tier: str, *,
             priority: float = 0.0) -> str:
        key = self.key_of(label)
        self.pool.put(key, value, tier, priority=priority)
        self.parks += 1
        return key

    def prefetch(self, label: str) -> TransferHandle:
        return self.pool.prefetch(self.keys[label])

    def fetch(self, label: str) -> jax.Array:
        return self.pool.get(self.keys[label])

    def tiers(self) -> dict:
        """label -> tier currently holding the page (spill visibility)."""
        return {lb: self.pool.tier_of(k) for lb, k in self.keys.items()
                if k in self.pool}

    def drop(self) -> None:
        """Retire the request: drop every page still in the pool."""
        for k in self.keys.values():
            if k in self.pool:
                self.pool.drop(k)
        self.keys.clear()


def worst_case_page_bytes(cache_specs) -> int:
    """Worst-case pool footprint of one request's pages: the full
    per-request cache row at max_seq (``Model.cache_specs(1, max_seq)``),
    summed over every leaf. Used by admission control before any page
    exists."""
    total = 0
    for leaf in jax.tree.leaves(cache_specs):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * jnp.dtype(leaf.dtype).itemsize
    return int(total)


@jax.jit
def _page_summary(k_page: jax.Array) -> jax.Array:
    """(B, page, Hkv, D) -> (B, Hkv, D) mean key."""
    return jnp.mean(k_page, axis=1)


@dataclasses.dataclass
class PrefetchedPages:
    """In-flight page fetches; ``wait()`` yields what ``fetch_pages``
    would have returned synchronously, plus the page indices."""

    idx: np.ndarray
    k_handles: List[TransferHandle]
    v_handles: List[TransferHandle]
    _shape: Tuple[int, ...]
    _dtype: jnp.dtype

    def wait(self) -> Tuple[jax.Array, jax.Array, np.ndarray]:
        if not self.k_handles:
            empty = jnp.zeros((0,) + self._shape, self._dtype)
            return empty, empty, self.idx
        ks = jnp.stack([h.wait() for h in self.k_handles])
        vs = jnp.stack([h.wait() for h in self.v_handles])
        return ks, vs, self.idx


@dataclasses.dataclass
class PagedKVCache:
    """One attention layer's paged cache. ``n_layers`` instances make a model."""

    page_size: int
    n_pages: int               # pool capacity in pages
    batch: int
    n_kv_heads: int
    head_dim: int
    dtype: jnp.dtype

    pool: MemoryPoolManager    # tiered page store (host tier by default)
    k_pool: List[Optional[str]]   # per page: pool key of the K page, or None
    v_pool: List[Optional[str]]
    k_summary: jax.Array       # (n_pages, B, Hkv, D) — device
    k_tail: jax.Array          # (B, page, Hkv, D) — device (partial page)
    v_tail: jax.Array
    length: int = 0            # tokens appended so far
    fetches: int = 0           # pool→device page transfers (stats)
    flushes: int = 0           # device→pool page stores
    key_ns: str = ""           # pool-key namespace (unique per instance)

    # -- fused-decode device page buffer (attend_fused) ----------------
    # LRU slot cache of decoded pages on device: the fused path attends
    # over it in place via a page table, so steady-state decode does ZERO
    # pool round trips (the gather path does ~2·n_pages per step)
    device_pages: Optional[int] = None   # slot budget; None → all pages
    use_kernel: bool = False             # Pallas kernel vs exact jnp ref
    buffer_hits: int = 0
    buffer_misses: int = 0
    _kbuf: Optional[jax.Array] = None    # (n_slots, B, page, Hkv, D)
    _vbuf: Optional[jax.Array] = None
    _slot_of: Dict[int, int] = dataclasses.field(default_factory=dict)
    _slot_page: List[Optional[int]] = dataclasses.field(default_factory=list)
    _slot_use: List[int] = dataclasses.field(default_factory=list)
    _use_clock: int = 0

    # ------------------------------------------------------------------
    @classmethod
    def create(cls, *, batch: int, max_seq: int, page_size: int,
               n_kv_heads: int, head_dim: int, dtype=jnp.float32,
               pool: Optional[MemoryPoolManager] = None,
               device_pages: Optional[int] = None,
               use_kernel: bool = False) -> "PagedKVCache":
        n_pages = -(-max_seq // page_size)
        if pool is None:
            raise ValueError(
                "PagedKVCache.create() requires a pool; construct caches "
                "through repro.api.HyperOffloadSession.paged_kv "
                "(mode='paged')")
        if device_pages is not None and device_pages < 1:
            raise ValueError("device_pages must be >= 1 (or None = all)")
        pool.transfer.ensure_depth(auto_depth(pages=n_pages))
        return cls(
            page_size=page_size, n_pages=n_pages, batch=batch,
            n_kv_heads=n_kv_heads, head_dim=head_dim, dtype=dtype,
            pool=pool,
            k_pool=[None] * n_pages, v_pool=[None] * n_pages,
            k_summary=jnp.zeros((n_pages, batch, n_kv_heads, head_dim), dtype),
            k_tail=jnp.zeros((batch, page_size, n_kv_heads, head_dim), dtype),
            v_tail=jnp.zeros((batch, page_size, n_kv_heads, head_dim), dtype),
            key_ns=f"kvcache{next(_CACHE_IDS)}",
            device_pages=device_pages, use_kernel=use_kernel,
        )

    @property
    def full_pages(self) -> int:
        return self.length // self.page_size

    @property
    def tail_len(self) -> int:
        return self.length % self.page_size

    def pool_stats(self) -> dict:
        return self.pool.snapshot()

    def close(self) -> None:
        """The (always caller-provided, possibly shared) pool is its
        owner's to close; nothing per-cache needs shutting down."""

    # ------------------------------------------------------------------
    def _store_page(self, page_idx: int, k_page: jax.Array,
                    v_page: jax.Array) -> None:
        # recent pages rank higher for sparse selection → keep them closest
        kk = f"{self.key_ns}/k{page_idx}"
        vk = f"{self.key_ns}/v{page_idx}"
        self.pool.put(kk, k_page, priority=float(page_idx))
        self.pool.put(vk, v_page, priority=float(page_idx))
        self.k_pool[page_idx] = kk
        self.v_pool[page_idx] = vk
        self.flushes += 1
        if self._kbuf is not None:
            # install at flush: the newest page is the hottest, and taking
            # it from the tail (not a pool fetch-back) keeps the buffer
            # exact even when a codec quantizes the pool copy
            self._install_page(page_idx, k_page, v_page)

    def _flush_tail(self) -> None:
        """Store: commit the full tail page to the pool + update summary."""
        page_idx = self.length // self.page_size - 1
        self._store_page(page_idx, self.k_tail, self.v_tail)
        self.k_summary = self.k_summary.at[page_idx].set(
            _page_summary(self.k_tail))

    def append(self, k_t: jax.Array, v_t: jax.Array) -> None:
        """Append one token's K/V: (B, Hkv, D)."""
        i = self.tail_len
        self.k_tail = self.k_tail.at[:, i].set(k_t.astype(self.dtype))
        self.v_tail = self.v_tail.at[:, i].set(v_t.astype(self.dtype))
        self.length += 1
        if self.length % self.page_size == 0:
            self._flush_tail()

    def prefill(self, k_seq: jax.Array, v_seq: jax.Array) -> None:
        """Bulk-append a prompt: (B, S, Hkv, D)."""
        s = k_seq.shape[1]
        n_full = s // self.page_size
        for pi in range(n_full):
            sl = slice(pi * self.page_size, (pi + 1) * self.page_size)
            kp = k_seq[:, sl].astype(self.dtype)
            vp = v_seq[:, sl].astype(self.dtype)
            self._store_page(pi, kp, vp)
            self.k_summary = self.k_summary.at[pi].set(_page_summary(kp))
        rem = s - n_full * self.page_size
        if rem:
            self.k_tail = self.k_tail.at[:, :rem].set(
                k_seq[:, n_full * self.page_size:].astype(self.dtype))
            self.v_tail = self.v_tail.at[:, :rem].set(
                v_seq[:, n_full * self.page_size:].astype(self.dtype))
        self.length = s

    # ------------------------------------------------------------------
    def select_pages(self, q: jax.Array, top_k: Optional[int]) -> np.ndarray:
        """Sparse block selection: rank full pages by mean-key relevance to
        the query (B, Hq, D) → sorted page indices (host ints)."""
        n = self.full_pages
        if n == 0:
            return np.zeros((0,), np.int64)
        if top_k is None or top_k >= n:
            return np.arange(n)
        summ = self.k_summary[:n]                     # (n, B, Hkv, D)
        qm = jnp.mean(q.astype(jnp.float32), axis=(0, 1))   # (D,)
        scores = jnp.einsum("nbhd,d->n", summ.astype(jnp.float32), qm)
        idx = np.asarray(jax.lax.top_k(scores, top_k)[1])
        return np.sort(idx)

    def _page_shape(self) -> Tuple[int, ...]:
        return (self.batch, self.page_size, self.n_kv_heads, self.head_dim)

    def fetch_pages(self, idx: Sequence[int]) -> Tuple[jax.Array, jax.Array]:
        """Prefetch (sync): copy the selected pool pages to device memory.
        Returns (n_sel, B, page, Hkv, D) device arrays."""
        if len(idx) == 0:
            shape = (0,) + self._page_shape()
            return jnp.zeros(shape, self.dtype), jnp.zeros(shape, self.dtype)
        ks = [self.pool.get(self.k_pool[int(i)]) for i in idx]
        vs = [self.pool.get(self.v_pool[int(i)]) for i in idx]
        self.fetches += len(idx)
        return jnp.stack(ks), jnp.stack(vs)

    def prefetch_pages(self, idx: Sequence[int]) -> PrefetchedPages:
        """Prefetch (async): issue page fetches through the pool's transfer
        engine; the caller overlaps compute and calls ``.wait()`` at use."""
        idx = np.asarray(idx, np.int64)
        kh = [self.pool.prefetch(self.k_pool[int(i)]) for i in idx]
        vh = [self.pool.prefetch(self.v_pool[int(i)]) for i in idx]
        self.fetches += len(idx)
        return PrefetchedPages(idx=idx, k_handles=kh, v_handles=vh,
                               _shape=self._page_shape(), _dtype=self.dtype)

    # ------------------------------------------------------------------
    def attend(self, q: jax.Array, *, scale: float,
               top_k_pages: Optional[int] = None,
               prefetched=None) -> jax.Array:
        """Decode attention of q (B, Hq, D) over selected pages + tail.
        ``prefetched`` — a ``PrefetchedPages`` or an already-waited
        (k, v, idx) tuple — lets the engine overlap next-step fetches."""
        if prefetched is not None:
            if isinstance(prefetched, PrefetchedPages):
                kp, vp, idx = prefetched.wait()
            else:
                kp, vp, idx = prefetched
        else:
            idx = self.select_pages(q, top_k_pages)
            kp, vp = self.fetch_pages(idx)
        return _paged_attend(q, kp, vp, self.k_tail, self.v_tail,
                             jnp.int32(self.tail_len), scale)

    # -- fused decode over the device page buffer ----------------------
    @property
    def n_slots(self) -> int:
        return self.device_pages if self.device_pages is not None \
            else self.n_pages

    def _ensure_buffer(self) -> None:
        if self._kbuf is None:
            shape = (self.n_slots,) + self._page_shape()
            self._kbuf = jnp.zeros(shape, self.dtype)
            self._vbuf = jnp.zeros(shape, self.dtype)
            self._slot_page = [None] * self.n_slots
            self._slot_use = [0] * self.n_slots

    def _touch(self, slot: int) -> None:
        self._use_clock += 1
        self._slot_use[slot] = self._use_clock

    def _alloc_slot(self, keep: frozenset) -> int:
        """A free slot, else the LRU slot whose page is not needed this
        step; its old page stays safe in the pool (the buffer is a cache,
        never the only copy of a flushed page)."""
        victims = [s for s in range(self.n_slots)
                   if self._slot_page[s] is None
                   or self._slot_page[s] not in keep]
        if not victims:
            raise ValueError(
                f"device_pages={self.n_slots} is smaller than one step's "
                "page selection; raise the budget or lower top_k_pages")
        slot = min(victims, key=lambda s: (self._slot_page[s] is not None,
                                           self._slot_use[s]))
        old = self._slot_page[slot]
        if old is not None:
            del self._slot_of[old]
        return slot

    def _install_page(self, page_idx: int, k_page: jax.Array,
                      v_page: jax.Array, keep: frozenset = frozenset()) -> None:
        slot = self._slot_of.get(page_idx)
        if slot is None:
            slot = self._alloc_slot(keep)
            self._slot_of[page_idx] = slot
            self._slot_page[slot] = page_idx
        self._kbuf = self._kbuf.at[slot].set(k_page.astype(self.dtype))
        self._vbuf = self._vbuf.at[slot].set(v_page.astype(self.dtype))
        self._touch(slot)

    def _ensure_resident(self, idx: Sequence[int]) -> np.ndarray:
        """Map the selected page indices onto buffer slots, fetching
        misses from the pool (decoded). Returns the slot table the fused
        kernel/ref walks."""
        self._ensure_buffer()
        need = frozenset(int(i) for i in idx)
        slots = []
        for i in idx:
            i = int(i)
            slot = self._slot_of.get(i)
            if slot is None:
                self.buffer_misses += 1
                self.fetches += 1
                self._install_page(i, self.pool.get(self.k_pool[i]),
                                   self.pool.get(self.v_pool[i]), keep=need)
                slot = self._slot_of[i]
            else:
                self.buffer_hits += 1
                self._touch(slot)
            slots.append(slot)
        return np.asarray(slots, np.int64)

    def attend_fused(self, q: jax.Array, *, scale: float,
                     top_k_pages: Optional[int] = None,
                     use_kernel: Optional[bool] = None) -> jax.Array:
        """Fused decode attention of q (B, Hq, D) over selected pages +
        tail — same selection and same merged-softmax semantics as
        ``attend``, but over the device page buffer via a page table:
        no per-step gather/concat pool round trip. Steady state (all
        selected pages resident) touches the pool zero times per step.

        ``use_kernel=False`` (instance default) runs the jitted exact-math
        reference — bit-identical to ``attend`` for resident pages, which
        is what makes codec-"none" serving token-identical; ``True`` runs
        the Pallas online-softmax kernel (parity-tested to 2e-5 in f32,
        interpret mode on CPU)."""
        idx = self.select_pages(q, top_k_pages)
        slots = self._ensure_resident(idx)
        table = jnp.asarray(slots, jnp.int32)
        if use_kernel is None:
            use_kernel = self.use_kernel
        if use_kernel:
            from repro.kernels.ops import paged_decode_attention
            return paged_decode_attention(
                q, self._kbuf, self._vbuf, table, self.k_tail, self.v_tail,
                jnp.int32(self.tail_len), scale=scale)
        return _fused_attend_ref(q, self._kbuf, self._vbuf, table,
                                 self.k_tail, self.v_tail,
                                 jnp.int32(self.tail_len), scale=scale)


@jax.jit
def _segment_scores(q, k, scale):
    """q (B,Hq,D), k (B,T,Hkv,D) -> scores (B,Hq,T) in f32 (GQA aware)."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    return jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)).reshape(
        b, hq, k.shape[1])


@jax.jit
def _paged_attend(q, k_pages, v_pages, k_tail, v_tail, tail_len, scale):
    """Exact attention over [pages ++ tail] in one merged softmax."""
    b, hq, d = q.shape
    n, _, page, hkv, _ = k_pages.shape
    k_flat = k_pages.transpose(1, 0, 2, 3, 4).reshape(b, n * page, hkv, d)
    v_flat = v_pages.transpose(1, 0, 2, 3, 4).reshape(b, n * page, hkv, d)
    s_pages = _segment_scores(q, k_flat, scale)              # (B,Hq,n*page)
    s_tail = _segment_scores(q, k_tail, scale)               # (B,Hq,page)
    t_mask = jnp.arange(k_tail.shape[1]) < tail_len
    s_tail = jnp.where(t_mask[None, None, :], s_tail, NEG_INF)
    s = jnp.concatenate([s_pages, s_tail], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    v_all = jnp.concatenate([v_flat, v_tail], axis=1)        # (B,T,Hkv,D)
    g = hq // hkv
    pf = p.reshape(b, hkv, g, -1)
    out = jnp.einsum("bkgt,btkd->bkgd", pf, v_all.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
