"""JAX-native HyperOffload runtime integration.

Three concrete lowerings of the paper's cache operators onto mechanisms XLA
already understands (DESIGN.md §2):

- ``policies``  — activation offload via offload-aware rematerialization
  policies (checkpoint_name'd residuals → ``pinned_host``), §5.1 case 1;
- ``optstate``  — optimizer-state host offload via memory-kind shardings,
  §5.1 case 2;
- ``kvcache``   — paged KV cache with a host-side pool and double-buffered
  block prefetch for decode, §5.2.
"""

from repro.offload.policies import offload_remat_policy, remat_policy
from repro.offload.optstate import host_offload_state, device_fetch_state
from repro.offload.kvcache import PagedKVCache

__all__ = [
    "offload_remat_policy",
    "remat_policy",
    "host_offload_state",
    "device_fetch_state",
    "PagedKVCache",
]
