"""Async double-buffered transfer engine with explicit wait handles.

The planner's whole premise is that Prefetch traffic overlaps compute; a
synchronous ``device_put`` at the use site serializes it instead. This
engine issues transfers on worker threads ahead of use and hands back a
``TransferHandle`` the consumer waits on — the runtime analogue of the
timeline simulator's copy-stream model. ``depth`` bounds in-flight
transfers (classic double buffering at the default of 2): submitting past
the bound first retires the oldest outstanding transfer, so a runaway
prefetcher cannot flood host bandwidth or pile up staging buffers.

Stats distinguish waits that found the transfer already complete (fully
overlapped) from waits that blocked (exposed transfer time) — the runtime
counterpart of ``Timeline.exposed_comm``.

With a tracer attached (``repro.obs``) every handle additionally emits two
trace spans: ``transfer`` (execution start → complete on the worker
thread, tagged with its source/destination tiers — queue time spent
waiting for a worker is *excluded*, it shows up as backpressure/in-flight
depth instead, so a saturated engine can't masquerade queueing delay as
hidden transfer time) and ``transfer.wait`` (first consumer wait, tagged
hit/blocked) from the consumer — the raw material ``obs.OverlapAnalyzer``
decomposes into hidden vs exposed transfer time. The wait span's duration
is the *same measurement* added to ``blocked_s``, so trace and counters
can be cross-validated exactly.

Per tier-pair byte/busy-time accounting (``TransferStats.pairs``) feeds
the calibration loop (``core.calibration``): every transfer that declares
``src``/``dst`` and a byte count records its measured execution time under
``"src->dst"``, and the pool reports its synchronous puts/spills through
``record_pair`` — together the measured bandwidth table ``recalibrate()``
turns into a ``CalibratedHardwareSpec``.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Optional

from repro.obs.trace import NULL_TRACER

#: floor for the auto depth policy — always enough for classic double
#: buffering plus a few leaves of headroom
MIN_AUTO_DEPTH = 8


def auto_depth(*, layers: Optional[int] = None, pages: Optional[int] = None,
               minimum: int = MIN_AUTO_DEPTH) -> int:
    """The one transfer-depth policy (``OffloadConfig.transfer_depth="auto"``).

    Depth is sized so one step's worth of fetches issues completely before
    anything waits, while still bounding staging memory:

    - whole-cache round trips (``ServeEngine``): 2 K/V leaves per layer plus
      2× headroom → ``4 * layers``;
    - page-granular prefetch (scheduler / ``PagedKVCache``): every page's
      K and V fetch in flight at once → ``2 * pages``.

    Callers pass whichever dimensions they know; the policy takes the max.
    This replaces the per-call-site magic numbers the subsystems used to
    hard-code.
    """
    depth = int(minimum)
    if layers:
        depth = max(depth, 4 * int(layers))
    if pages:
        depth = max(depth, 2 * int(pages))
    return depth


@dataclass
class TransferStats:
    issued: int = 0
    completed: int = 0
    waits_overlapped: int = 0   # consumer wait() found the transfer done
    waits_blocked: int = 0      # consumer wait() had to block (exposed time)
    blocked_s: float = 0.0      # total consumer-exposed transfer time
    backpressure_waits: int = 0  # submits stalled by a full pipeline
    backpressure_s: float = 0.0  # time submit() spent retiring transfers
    max_in_flight: int = 0
    #: measured per tier-pair movement, keyed "src->dst": each entry holds
    #: {transfers, bytes, busy_s} where busy_s is summed per-transfer
    #: execution time (NOT wall time — concurrent transfers double-count,
    #: so bytes/busy_s is per-stream bandwidth, the number a planner's
    #: transfer_time() estimate should match)
    pairs: Dict[str, Dict[str, float]] = field(default_factory=dict)

    def record_pair(self, src: str, dst: str, nbytes: int,
                    seconds: float) -> None:
        b = self.pairs.setdefault(f"{src}->{dst}",
                                  {"transfers": 0, "bytes": 0, "busy_s": 0.0})
        b["transfers"] += 1
        b["bytes"] += int(nbytes)
        b["busy_s"] += float(seconds)

    def snapshot(self) -> Dict[str, float]:
        return {
            "issued": self.issued, "completed": self.completed,
            "waits_overlapped": self.waits_overlapped,
            "waits_blocked": self.waits_blocked,
            "blocked_s": self.blocked_s,
            "backpressure_waits": self.backpressure_waits,
            "backpressure_s": self.backpressure_s,
            "max_in_flight": self.max_in_flight,
            "pairs": {k: dict(v) for k, v in self.pairs.items()},
        }


class TransferHandle:
    """One in-flight transfer. ``wait()`` returns its value (idempotent)."""

    def __init__(self, key: Optional[str], seq: int, future: "Future",
                 engine: "TransferEngine") -> None:
        self.key = key
        self.seq = seq          # issue order — lets tests assert issue-before-wait
        self._future = future
        self._engine = engine
        self._waited = False

    @property
    def done(self) -> bool:
        return self._future.done()

    def wait(self) -> Any:
        """Idempotent; only the first wait is charged to the stats (and
        traced), so re-waiting (or an engine-internal retirement) never
        double-counts."""
        was_done = self._future.done()
        t0 = time.perf_counter()
        value = self._future.result()
        if not self._waited:
            self._waited = True
            dur = time.perf_counter() - t0
            self._engine._record_wait(was_done, dur)
            tracer = self._engine.tracer
            if tracer.enabled:
                tracer.complete("transfer", "transfer.wait", t0, dur,
                                {"seq": self.seq, "key": self.key,
                                 "hit": was_done})
        return value

    def __repr__(self) -> str:
        state = "done" if self.done else "in-flight"
        return f"TransferHandle({self.key!r}, seq={self.seq}, {state})"


class TransferEngine:
    def __init__(self, depth: int = 2, workers: int = 2,
                 tracer=None) -> None:
        if depth < 1:
            raise ValueError("depth must be >= 1")
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.depth = depth
        self.depth_pinned = False   # True ⇒ ensure_depth is a no-op
        self.workers = workers
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="pool-xfer")
        self._in_flight: Deque[TransferHandle] = deque()
        self._lock = threading.Lock()
        self._seq = 0
        self.stats = TransferStats()
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def set_tracer(self, tracer) -> None:
        """Attach/replace the tracer (the session wires its telemetry into
        an injected engine after construction)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def ensure_depth(self, depth: int) -> None:
        """Raise the in-flight bound to at least ``depth`` (never lowers).

        A shared engine serves every subsystem of a session: each consumer
        declares the depth its issue pattern needs (via ``auto_depth``) and
        the engine grows to cover the largest one. An explicitly pinned
        depth (``OffloadConfig(transfer_depth=<int>)``) is never raised."""
        with self._lock:
            if not self.depth_pinned:
                self.depth = max(self.depth, int(depth))

    def ensure_workers(self, workers: int) -> None:
        """Raise the worker-thread count to at least ``workers`` (never
        lowers). This is the knob the calibration loop turns: on a
        latency-dominated tier, sustained throughput needs in-flight
        parallelism up to the measured bandwidth-delay product, and worker
        threads are what bound genuine concurrency (depth only bounds
        queued submissions). Drains outstanding transfers, then swaps the
        executor — safe at a step boundary, where every consumer has
        already waited."""
        workers = int(workers)
        if workers <= self.workers:
            return
        self.drain()
        old = self._pool
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="pool-xfer")
        self.workers = workers
        old.shutdown(wait=True)

    def record_pair(self, src: str, dst: str, nbytes: int,
                    seconds: float) -> None:
        """Record one synchronous transfer into the per-pair table (the
        pool's blocking puts and spills — movement that never goes through
        ``submit`` but that calibration still needs to see)."""
        with self._lock:
            self.stats.record_pair(src, dst, nbytes, seconds)

    # ------------------------------------------------------------------
    def submit(self, fn: Callable[[], Any], key: Optional[str] = None, *,
               src: Optional[str] = None,
               dst: Optional[str] = None,
               nbytes: Optional[int] = None) -> TransferHandle:
        """Issue ``fn`` (a transfer thunk) asynchronously. Blocks on the
        oldest outstanding transfer first when the pipeline is full —
        charged to backpressure stats, not consumer-exposed time (the
        consumer's own later wait() on that handle still counts normally).
        Thread-safe: concurrent submitters share the depth bound.
        ``src``/``dst`` name the tiers the bytes move between; with
        ``nbytes`` they additionally record the transfer's measured
        execution time into the per-pair calibration table."""
        while True:
            with self._lock:
                self._reap_locked()
                if len(self._in_flight) < self.depth:
                    self._seq += 1
                    seq = self._seq
                    self.stats.issued += 1

                    def run():
                        t_start = time.perf_counter()
                        try:
                            return fn()
                        finally:
                            t_done = time.perf_counter()
                            with self._lock:
                                self.stats.completed += 1
                                if src and dst and nbytes is not None:
                                    self.stats.record_pair(
                                        src, dst, nbytes, t_done - t_start)
                            if self.tracer.enabled:
                                self.tracer.complete(
                                    "transfer", "transfer", t_start,
                                    t_done - t_start,
                                    {"seq": seq, "key": key,
                                     "src": src, "dst": dst})

                    handle = TransferHandle(key, seq,
                                            self._pool.submit(run), self)
                    self._in_flight.append(handle)
                    self.stats.max_in_flight = max(self.stats.max_in_flight,
                                                   len(self._in_flight))
                    return handle
                oldest = self._in_flight.popleft()
            # never block on a future while holding the lock — the worker's
            # completion accounting needs it. A failed transfer's exception
            # belongs to its own handle's wait(), not to this submitter.
            t0 = time.perf_counter()
            try:
                oldest._future.result()
            except Exception:
                pass
            dur = time.perf_counter() - t0
            with self._lock:
                self.stats.backpressure_waits += 1
                self.stats.backpressure_s += dur
            if self.tracer.enabled:
                self.tracer.complete("transfer", "transfer.backpressure",
                                     t0, dur, {"stalled_on": oldest.seq})

    def drain(self) -> None:
        """Retire every outstanding transfer. Failed transfers don't stop
        the drain — their exceptions stay with their handles."""
        while True:
            with self._lock:
                if not self._in_flight:
                    return
                oldest = self._in_flight.popleft()
            try:
                oldest.wait()
            except Exception:
                pass

    def close(self) -> None:
        self.drain()
        self._pool.shutdown(wait=True)

    # ------------------------------------------------------------------
    def _reap_locked(self) -> None:
        while self._in_flight and self._in_flight[0].done:
            self._in_flight.popleft()

    def _record_wait(self, was_done: bool, blocked_s: float) -> None:
        with self._lock:
            if was_done:
                self.stats.waits_overlapped += 1
            else:
                self.stats.waits_blocked += 1
                self.stats.blocked_s += blocked_s
