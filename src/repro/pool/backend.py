"""Tiered memory backends behind one interface (§5 remote memory backend).

Backends are selected per-tier by a declarative ``TierSpec.kind``
(``pool.topology``); the default chain mirrors the paper's hierarchy:

- **device** — accelerator HBM (JAX default memory);
- **host**   — ``pinned_host`` memory-kind shardings where the platform
  supports them (TPU/GPU), degrading to ``unpinned_host`` and finally to
  plain NumPy host buffers where memory-kind shardings raise (XLA:CPU only
  addresses ``unpinned_host``; some builds address nothing but the default);
- **modeled** — the disaggregated pooled-DRAM stand-in (CloudMatrix /
  CXL-hybrid tier): NumPy storage behind a sleep-throttle that *enforces*
  the spec's per-direction bandwidth and latency, so the runtime feels —
  and the telemetry measures — a configurable transfer character instead
  of whatever the host happens to do. Unthrottled it degenerates to the
  old plain-NumPy remote tier.

Capability probing happens once per device and is cached; every offload
call site (kv pages, optimizer moments, plan execution) routes through the
probe instead of hard-coding ``pinned_host`` — that hard-coding is exactly
why the seed's offload runtime failed on CPU backends.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.pool import codec as codec_mod

DEVICE_TIER = "device"
HOST_TIER = "host"
REMOTE_TIER = "remote"

# preference order for the host tier's memory kind
_HOST_KIND_PREFERENCE = ("pinned_host", "unpinned_host")


@dataclass(frozen=True)
class Capabilities:
    """What one device can address, probed once."""

    platform: str
    memory_kinds: Tuple[str, ...]      # addressable kinds ("" if unknown)
    default_kind: Optional[str]        # the device's default memory kind
    host_kind: Optional[str]           # best host kind, None → NumPy fallback

    @property
    def supports_host_sharding(self) -> bool:
        return self.host_kind is not None


def _probe(device) -> Capabilities:
    kinds: Tuple[str, ...] = ()
    default = None
    try:
        kinds = tuple(m.kind for m in device.addressable_memories())
        default = device.default_memory().kind
    except Exception:  # very old jaxlib: no memories API
        pass
    host = next((k for k in _HOST_KIND_PREFERENCE if k in kinds), None)
    if host is not None:
        # the kind being listed is not enough on every build — a put must work
        try:
            s = jax.sharding.SingleDeviceSharding(device, memory_kind=host)
            jax.device_put(np.zeros(1, np.uint8), s)
        except Exception:
            host = None
    return Capabilities(platform=device.platform, memory_kinds=kinds,
                        default_kind=default, host_kind=host)


@functools.lru_cache(maxsize=None)
def _capabilities_cached(device) -> Capabilities:
    return _probe(device)


def capabilities(device=None) -> Capabilities:
    return _capabilities_cached(device if device is not None else jax.devices()[0])


def host_memory_kind(device=None) -> Optional[str]:
    """Best host memory kind for this device, or None (→ NumPy fallback)."""
    return capabilities(device).host_kind


def device_sharding(device=None) -> jax.sharding.SingleDeviceSharding:
    d = device if device is not None else jax.devices()[0]
    return jax.sharding.SingleDeviceSharding(d)


def host_sharding(device=None) -> Optional[jax.sharding.SingleDeviceSharding]:
    d = device if device is not None else jax.devices()[0]
    kind = host_memory_kind(d)
    if kind is None:
        return None
    return jax.sharding.SingleDeviceSharding(d, memory_kind=kind)


# ---------------------------------------------------------------------------
# single-array transfer helpers (used by optstate / jax_exec)
# ---------------------------------------------------------------------------


def to_host(x, device=None):
    """Store one array in host memory: memory-kind sharding if supported,
    else a NumPy buffer (forces the device→host copy either way)."""
    s = host_sharding(device)
    if s is None:
        return np.asarray(x)
    return jax.device_put(x, s)


def to_device(x, device=None) -> jax.Array:
    """Prefetch one array (jax host-kind array or NumPy buffer) to device."""
    return jax.device_put(x, device_sharding(device))


def is_host_resident(x, device=None) -> bool:
    """True if ``x`` lives in the host tier (however this platform spells
    it). On probe-less builds only NumPy buffers count — a jax array's
    memory kind can't be trusted to mean "host" there."""
    if isinstance(x, np.ndarray):
        return True
    want = host_memory_kind(device)
    if want is None:
        return False
    return getattr(getattr(x, "sharding", None), "memory_kind", None) == want


# ---------------------------------------------------------------------------
# backend objects (the pool manager's tier storage)
# ---------------------------------------------------------------------------


class MemoryBackend:
    """One storage tier: ``put`` stores a device array into the tier and
    returns an opaque handle; ``get`` materializes a handle on device."""

    name: str = "abstract"

    def put(self, value) -> Any:
        raise NotImplementedError

    def get(self, handle) -> jax.Array:
        raise NotImplementedError

    def nbytes(self, handle) -> int:
        return int(handle.nbytes)

    def wire_nbytes(self, value) -> int:
        """Bytes a ``put(value)`` will move over the wire and occupy at
        rest in this tier — what capacity accounting and the transfer
        telemetry must charge. Identity for plain tiers; a codec-wrapped
        tier reports the *encoded* size."""
        return int(value.nbytes)

    def holds(self, handle) -> bool:
        """Residency check: does the handle live where this tier claims?"""
        raise NotImplementedError


class DeviceBackend(MemoryBackend):
    """Accelerator HBM — JAX default memory."""

    name = "device"

    def __init__(self, device=None) -> None:
        self.device = device if device is not None else jax.devices()[0]
        self._sharding = device_sharding(self.device)

    def put(self, value) -> jax.Array:
        return jax.device_put(value, self._sharding)

    def get(self, handle) -> jax.Array:
        return handle

    def holds(self, handle) -> bool:
        return isinstance(handle, jax.Array)


class JaxHostBackend(MemoryBackend):
    """Host memory via memory-kind shardings (pinned_host / unpinned_host)."""

    def __init__(self, device=None, kind: Optional[str] = None) -> None:
        self.device = device if device is not None else jax.devices()[0]
        self.kind = kind if kind is not None else host_memory_kind(self.device)
        if self.kind is None:
            raise ValueError(
                f"device {self.device} addresses no host memory kind; "
                "use NumpyHostBackend")
        self.name = f"jax-host[{self.kind}]"
        self._host = jax.sharding.SingleDeviceSharding(
            self.device, memory_kind=self.kind)
        self._dev = device_sharding(self.device)

    def put(self, value) -> jax.Array:
        return jax.device_put(value, self._host)

    def get(self, handle) -> jax.Array:
        return jax.device_put(handle, self._dev)

    def holds(self, handle) -> bool:
        return getattr(getattr(handle, "sharding", None),
                       "memory_kind", None) == self.kind


class NumpyHostBackend(MemoryBackend):
    """Plain NumPy host buffers — the simulated remote pool, and the
    last-resort host tier on platforms with no memory-kind support.
    ``np.asarray`` blocks until the device→host copy lands, so a handle is
    always a fully materialized host buffer."""

    name = "numpy-host"

    def __init__(self, device=None) -> None:
        self.device = device if device is not None else jax.devices()[0]
        self._dev = device_sharding(self.device)

    def put(self, value) -> np.ndarray:
        return np.asarray(value)

    def get(self, handle) -> jax.Array:
        return jax.device_put(handle, self._dev)

    def holds(self, handle) -> bool:
        return isinstance(handle, np.ndarray)


class ModeledTierBackend(MemoryBackend):
    """The modeled disaggregated tier: NumPy storage behind a throttle
    that enforces a configured transfer character. Each ``put`` sleeps out
    the remainder of ``write_latency_s + nbytes/write_bw`` past the time
    the real copy took (``get`` likewise with the read-direction numbers,
    after blocking until the device copy lands — enforced timing must
    cover the actual data movement, not an async dispatch). A ``None``
    bandwidth with zero latency disables the throttle for that direction,
    so an unthrottled modeled tier behaves exactly like the historical
    plain-NumPy remote tier.

    Throttling is per-transfer and independent across engine worker
    threads — concurrent transfers genuinely overlap, which is what makes
    the tier sweepable like a real link: aggregate throughput scales with
    in-flight parallelism up to the bandwidth-delay product, the dynamic
    the calibration loop (``core.calibration``) sizes prefetch workers
    against."""

    def __init__(self, device=None, *, read_bw: Optional[float] = None,
                 write_bw: Optional[float] = None,
                 read_latency_s: float = 0.0,
                 write_latency_s: float = 0.0,
                 name: str = "modeled") -> None:
        self.device = device if device is not None else jax.devices()[0]
        self._dev = device_sharding(self.device)
        self.read_bw = read_bw
        self.write_bw = write_bw
        self.read_latency_s = float(read_latency_s)
        self.write_latency_s = float(write_latency_s)
        self.name = name

    @property
    def throttled(self) -> bool:
        return (self.read_bw is not None or self.write_bw is not None
                or self.read_latency_s > 0 or self.write_latency_s > 0)

    @staticmethod
    def _throttle(t0: float, nbytes: int, bw: Optional[float],
                  latency_s: float) -> None:
        if bw is None and latency_s <= 0:
            return
        want = latency_s + (nbytes / bw if bw is not None else 0.0)
        remaining = want - (time.perf_counter() - t0)
        if remaining > 0:
            time.sleep(remaining)

    def put(self, value) -> np.ndarray:
        t0 = time.perf_counter()
        handle = np.asarray(value)   # blocks until the device→host copy lands
        self._throttle(t0, int(handle.nbytes), self.write_bw,
                       self.write_latency_s)
        return handle

    def get(self, handle) -> jax.Array:
        t0 = time.perf_counter()
        value = jax.device_put(handle, self._dev)
        if self.read_bw is not None or self.read_latency_s > 0:
            value.block_until_ready()
            self._throttle(t0, int(handle.nbytes), self.read_bw,
                           self.read_latency_s)
        return value

    def holds(self, handle) -> bool:
        return isinstance(handle, np.ndarray)


class CodecBackend(MemoryBackend):
    """A storage tier behind a KV page codec (``pool.codec``): encodes on
    ``put`` below the configured tier boundary, decodes on ``get``.

    The handle is an ``EncodedPage`` whose payload is stored through the
    wrapped backend, so the inner tier's character (memory-kind sharding,
    NumPy buffer, modeled sleep-throttle) applies to the *encoded* bytes —
    a throttled tier genuinely completes int8 pages ~4× faster, exactly
    the effect the codec exists to buy. Spilling an ``EncodedPage`` from
    one codec tier to another with the same codec moves the payload
    untouched: no decode/re-encode round trip, no compounding of
    quantization error. ``wire_nbytes``/``nbytes`` report the encoded
    size, which is what the pool's capacity accounting, the per tier-pair
    transfer table, and therefore ``core.calibration`` all see."""

    def __init__(self, inner: MemoryBackend, codec) -> None:
        self.inner = inner
        self.codec = codec
        self.name = f"{codec.name}[{inner.name}]"

    def put(self, value) -> "codec_mod.EncodedPage":
        if isinstance(value, codec_mod.EncodedPage):
            if value.codec != self.codec.name:
                raise ValueError(
                    f"cannot move a {value.codec!r}-encoded page into a "
                    f"{self.codec.name!r} tier without decoding first")
            # spill between codec tiers: move the encoded payload only
            return dataclasses.replace(
                value, payload=self.inner.put(value.payload))
        payload, scale = self.codec.encode(value)
        handle = self.inner.put(payload)
        return codec_mod.EncodedPage(
            codec=self.codec.name, payload=handle, scale=scale,
            dtype=str(value.dtype), shape=tuple(value.shape),
            nbytes=self.codec.encoded_nbytes(value.shape, value.dtype))

    def get(self, handle) -> jax.Array:
        payload = self.inner.get(handle.payload)
        return self.codec.decode(payload, handle.scale, handle.dtype)

    def nbytes(self, handle) -> int:
        return int(handle.nbytes)

    def wire_nbytes(self, value) -> int:
        if isinstance(value, codec_mod.EncodedPage):
            return int(value.nbytes)
        return self.codec.encoded_nbytes(value.shape, value.dtype)

    def holds(self, handle) -> bool:
        return (isinstance(handle, codec_mod.EncodedPage)
                and self.inner.holds(handle.payload))


def make_host_backend(device=None) -> MemoryBackend:
    """The best host-tier backend this platform supports."""
    if host_memory_kind(device) is not None:
        return JaxHostBackend(device)
    return NumpyHostBackend(device)


def make_backend(tier: str, device=None) -> MemoryBackend:
    if tier == DEVICE_TIER:
        return DeviceBackend(device)
    if tier == HOST_TIER:
        return make_host_backend(device)
    if tier == REMOTE_TIER:
        return NumpyHostBackend(device)
    raise ValueError(f"unknown tier {tier!r}")


def backend_for(spec, device=None) -> MemoryBackend:
    """Storage backend for one ``TierSpec`` (duck-typed on its fields —
    the spec type lives in ``pool.topology``; the dependency points this
    way so the topology module stays pure data)."""
    if spec.kind == "device":
        return DeviceBackend(device)
    if spec.kind == "host":
        return make_host_backend(device)
    if spec.kind == "numpy":
        return NumpyHostBackend(device)
    if spec.kind == "modeled":
        return ModeledTierBackend(
            device, read_bw=spec.read_bw, write_bw=spec.write_bw,
            read_latency_s=spec.read_latency_s,
            write_latency_s=spec.write_latency_s,
            name=f"modeled[{spec.name}]")
    raise ValueError(f"unknown tier kind {spec.kind!r}")
