"""Execute an ``OffloadPlan``'s refined node order against the real pool.

This closes the compiler→runtime loop: ``core.planner`` produces a graph
with cache operators plus a refined execution order; this executor walks
that order driving **real transfers** through the ``MemoryPoolManager`` —
``store`` parks the device array in the pool's host tier, ``prefetch``
issues an async fetch through the transfer engine at its scheduled
position (ahead of the consumer, which is exactly how Algorithm 1 hides
the copy), ``detach`` drops the device reference.

Alongside the values it maintains a byte-exact residency ledger under the
same IR memory semantics as ``core.memsim`` — activations are freed after
their last read, prefetches materialize at issue, detaches free — so tests
can assert the *executed* residency trace equals the *predicted* one:

    plan = HyperOffloadPlanner(hw).plan(g)
    _, trace = OffloadPlanExecutor(plan, pool).run(inputs)
    assert trace.usage == memsim.simulate(plan.graph, plan.order).usage

Compute nodes bind to user callables as in ``core.jax_exec``; unbound
computes (and missing inputs) materialize raw byte buffers of the declared
size, so a plan can be *driven* — real allocations, real pool traffic —
without a numerical model attached.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.ir import Graph
from repro.core.memsim import MemoryTrace
from repro.pool import backend as B
from repro.pool.manager import MemoryPoolManager, default_pool
from repro.pool.transfer import TransferHandle

# per-executor pool-key namespace: executors sharing one pool never collide
# on graphs that reuse tensor names
_EXEC_IDS = itertools.count()


@dataclass
class ExecutionTrace:
    """What actually happened: residency ledger + transfer counts."""

    usage: List[int] = field(default_factory=list)  # device bytes after each node
    peak_bytes: int = 0
    peak_pos: int = -1
    prefetches: int = 0
    stores: int = 0
    detaches: int = 0

    def matches(self, predicted: MemoryTrace) -> bool:
        """Executed residency equals memsim's prediction, node for node."""
        return (self.usage == predicted.usage
                and self.peak_bytes == predicted.peak_bytes)


class OffloadPlanExecutor:
    """Runs a planned graph; ``plan`` may be an ``OffloadPlan`` or a
    ``Graph`` (then ``order`` defaults to program order)."""

    def __init__(self, plan, pool: Optional[MemoryPoolManager] = None,
                 compute_fns: Optional[Mapping[str, Callable]] = None,
                 store_tier: Optional[str] = None) -> None:
        if isinstance(plan, Graph):
            self.graph, self.default_order = plan, plan.order()
        else:  # OffloadPlan (duck-typed: avoids a core←pool import cycle)
            self.graph, self.default_order = plan.graph, list(plan.order)
        self.pool = pool if pool is not None else default_pool()
        self.fns = dict(compute_fns or {})
        # default: wherever the pool's topology says offloaded stores land
        self.store_tier = (store_tier if store_tier is not None
                           else self.pool.default_store_tier)
        self._key_ns = f"exec{next(_EXEC_IDS)}"

    def _key(self, tensor: str) -> str:
        return f"{self._key_ns}/{tensor}"

    # ------------------------------------------------------------------
    def run(self, inputs: Optional[Mapping[str, Any]] = None,
            order: Optional[Sequence[str]] = None,
            ) -> Tuple[Dict[str, jax.Array], ExecutionTrace]:
        """Returns (final device environment, execution trace). ``inputs``
        provides values for graph inputs (weights/states); remote-initial
        tensors are parked in the pool before the walk starts."""
        graph = self.graph
        order = list(order) if order is not None else list(self.default_order)
        graph.validate_order(order)
        inputs = dict(inputs or {})
        pos = {n: i for i, n in enumerate(order)}

        # last read of each tensor under this order (memsim's free rule)
        last_read: Dict[str, int] = {}
        for name in order:
            for t in graph.nodes[name].reads():
                last_read[t] = pos[name]

        produced = {t for n in graph.nodes.values() for t in n.writes()
                    if n.kind == "compute"}

        env: Dict[str, jax.Array] = {}
        pending: Dict[str, TransferHandle] = {}
        cur = 0
        trace = ExecutionTrace()

        def materialize(t: str):
            if t in inputs:
                return inputs[t]
            return np.zeros(graph.tensors[t].nbytes, np.uint8)

        for t, info in graph.tensors.items():
            if info.initial_location == "remote":
                # standing remote copy (weights/states that start pooled);
                # prefetching soon — hint the pool not to churn it out
                self.pool.put(self._key(t), materialize(t), self.store_tier,
                              priority=float(len(order) - last_read.get(t, 0)))
            elif info.initial_location == "device" and t not in produced:
                env[t] = B.to_device(materialize(t))
                cur += info.nbytes
        trace.peak_bytes, trace.peak_pos = cur, -1

        def settle(t: str) -> None:
            if t in pending:
                env[t] = pending.pop(t).wait()

        def free(t: str) -> None:
            nonlocal cur
            if t in env or t in pending:
                settle(t)
                env.pop(t, None)
                cur -= graph.tensors[t].nbytes

        for i, name in enumerate(order):
            node = graph.nodes[name]
            if node.kind == "compute":
                for t in node.inputs:
                    settle(t)
                new = [t for t in node.outputs if t not in env and t not in pending]
                if name in self.fns:
                    outs = self.fns[name](*[env[t] for t in node.inputs])
                    if not isinstance(outs, (tuple, list)):
                        outs = (outs,)
                    if len(outs) != len(node.outputs):
                        raise ValueError(
                            f"{name}: fn returned {len(outs)} values for "
                            f"{len(node.outputs)} declared outputs")
                    for t, v in zip(node.outputs, outs):
                        env[t] = v
                else:
                    for t in node.outputs:
                        env[t] = B.to_device(materialize(t))
                cur += sum(graph.tensors[t].nbytes for t in new)
            elif node.kind == "prefetch":
                t = node.tensor
                if t not in env and t not in pending:
                    # async issue at the scheduled slot; the consumer waits
                    pending[t] = self.pool.prefetch(self._key(t))
                    cur += graph.tensors[t].nbytes
                    trace.prefetches += 1
            elif node.kind == "store":
                t = node.tensor
                settle(t)
                self.pool.put(self._key(t), env[t], self.store_tier,
                              priority=float(len(order) - i))
                trace.stores += 1
            elif node.kind == "detach":
                free(node.tensor)
                trace.detaches += 1
            # memsim's rule: activations die after their last read
            for t in node.reads():
                if (graph.tensors[t].klass == "activation"
                        and last_read.get(t, -1) == i):
                    free(t)
            if cur > trace.peak_bytes:
                trace.peak_bytes, trace.peak_pos = cur, i
            trace.usage.append(cur)

        for t in list(pending):
            settle(t)
        return env, trace
