"""Runtime memory-pool subsystem (§5 remote memory backend).

- ``topology`` — declarative ``TierTopology``: the spill chain as an
  ordered list of ``TierSpec``s (backend kind, capacity, admission role,
  modeled latency/bandwidth) instead of hard-coded tier strings;
- ``backend``  — tiered memory backends (device HBM / host memory-kind
  shardings / sleep-throttled modeled disaggregated tier) behind one
  interface, with per-device capability probing and graceful fallback;
- ``codec``    — int8/fp8 KV page codecs (per-page absmax scales); tiers
  below a configurable boundary store and move encoded payloads, so
  host/remote transfers carry 2–4× fewer bytes;
- ``manager``  — capacity-tracked ``MemoryPoolManager`` with
  priority+LRU eviction that spills down the declared tier chain;
- ``transfer`` — async double-buffered ``TransferEngine`` with explicit
  wait handles (prefetches genuinely overlap compute);
- ``executor`` — ``OffloadPlanExecutor`` runs a planned graph's refined
  order against the real pool and proves the executed residency trace
  matches ``core.memsim``'s prediction.
"""

from repro.pool.backend import (
    DEVICE_TIER, HOST_TIER, REMOTE_TIER,
    CodecBackend, ModeledTierBackend, backend_for, capabilities,
    device_sharding, host_memory_kind, host_sharding, is_host_resident,
    make_backend, make_host_backend, to_device, to_host,
)
from repro.pool.codec import (
    CODECS, EncodedPage, Fp8Codec, Int8Codec, KVCodec, make_codec,
    numpy_supports_fp8, roundtrip_bound,
)
from repro.pool.topology import TierSpec, TierTopology, sweep_topologies
from repro.pool.manager import (
    MemoryPoolManager, PoolCapacityError, PoolEntry, TierState, default_pool,
)
from repro.pool.transfer import (
    TransferEngine, TransferHandle, TransferStats, auto_depth,
)
from repro.pool.executor import ExecutionTrace, OffloadPlanExecutor

__all__ = [
    "DEVICE_TIER", "HOST_TIER", "REMOTE_TIER",
    "CODECS", "CodecBackend", "EncodedPage", "Fp8Codec", "Int8Codec",
    "KVCodec", "make_codec", "numpy_supports_fp8", "roundtrip_bound",
    "ModeledTierBackend", "backend_for",
    "capabilities", "device_sharding", "host_memory_kind", "host_sharding",
    "is_host_resident", "make_backend", "make_host_backend",
    "to_device", "to_host",
    "TierSpec", "TierTopology", "sweep_topologies",
    "MemoryPoolManager", "PoolCapacityError", "PoolEntry", "TierState",
    "default_pool",
    "TransferEngine", "TransferHandle", "TransferStats", "auto_depth",
    "ExecutionTrace", "OffloadPlanExecutor",
]
