"""Capacity-tracked memory-pool manager over the tiered backends.

``MemoryPoolManager`` owns an ordered spill chain of tiers, described
declaratively by a ``TierTopology`` (``default_pool`` builds the
historical device → host → remote chain when none is given). Each ``put``
is charged against the tier's byte capacity; when a tier is full, victims
are chosen by (planner priority, then LRU) among unpinned entries and
**spilled** to the next tier down the chain — the paper's hierarchy: HBM
overflows to the local host pool, the host pool overflows to the remote
pooled-DRAM tier — and an N-tier topology spills the same way, link by
link. Only when the last tier is full does a put fail with
``PoolCapacityError``.

Priorities are the planner's hint channel: the executor can mark a tensor
it will prefetch soon with a high priority so reactive churn never evicts
it — the graph-driven/reactive distinction at the heart of the paper.

All traffic is counted (puts/gets/evictions, bytes in/out, per-tier
occupancy and high-water mark); serving and benchmarks surface these via
``stats.snapshot()``. Synchronous movement (puts, spills, blocking gets)
additionally lands in the transfer engine's per tier-pair table, so the
calibration loop sees every byte the hierarchy moves, not just the async
prefetches.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import NULL_TRACER
from repro.pool import backend as B
from repro.pool import codec as codec_mod
from repro.pool.topology import TierTopology
from repro.pool.transfer import TransferEngine, TransferHandle


class PoolCapacityError(RuntimeError):
    """Every tier is full (after spilling) — the put cannot be honored."""


@dataclass
class PoolEntry:
    key: str
    tier: str
    handle: Any
    nbytes: int
    priority: float = 0.0      # higher → evicted later (planner hint)
    pinned: bool = False
    last_use: int = 0          # LRU clock


@dataclass
class TierState:
    name: str
    backend: B.MemoryBackend
    capacity: Optional[int] = None     # bytes; None → unbounded
    used: int = 0
    peak: int = 0

    def room_for(self, nbytes: int) -> bool:
        return self.capacity is None or self.used + nbytes <= self.capacity


@dataclass
class PoolStats:
    puts: int = 0
    gets: int = 0
    evictions: int = 0
    drops: int = 0
    bytes_stored: int = 0
    bytes_fetched: int = 0
    bytes_evicted: int = 0

    def snapshot(self) -> Dict[str, float]:
        return dict(self.__dict__)


class MemoryPoolManager:
    def __init__(self, tiers: Sequence[TierState],
                 transfer: Optional[TransferEngine] = None,
                 tracer=None, topology: Optional[TierTopology] = None) -> None:
        if not tiers:
            raise ValueError("need at least one tier")
        self.tiers: Dict[str, TierState] = {t.name: t for t in tiers}
        self.spill_order: List[str] = [t.name for t in tiers]
        self.topology = topology
        if topology is not None and list(topology.names) != self.spill_order:
            raise ValueError(
                f"topology names {topology.names} do not match tier states "
                f"{self.spill_order}")
        self.entries: Dict[str, PoolEntry] = {}
        self.transfer = transfer or TransferEngine()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None:
            self.transfer.set_tracer(tracer)
        self.stats = PoolStats()
        self._clock = 0
        self._lock = threading.RLock()
        # admission ledger: key -> (nbytes, tiers reserved against, covered
        # key prefix whose entries the reservation pays for)
        self._reservations: Dict[str, Tuple[int, Tuple[str, ...], Optional[str]]] = {}
        self._evict_listeners: List[Callable[[PoolEntry, str], None]] = []

    def set_tracer(self, tracer) -> None:
        """Attach/replace the tracer on the pool AND its transfer engine
        (the session wires its telemetry into an injected pool here)."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.transfer.set_tracer(tracer)

    # -- topology-derived roles ----------------------------------------
    @property
    def top_tier(self) -> str:
        """The chain's fastest tier — where compute-resident pages park."""
        return self.spill_order[0]

    @property
    def default_store_tier(self) -> str:
        """Where ``put`` lands when the caller names no tier: the
        topology's declared store tier, else the historical ``host``
        default when such a tier exists, else the first off-accelerator
        tier of the chain."""
        if self.topology is not None:
            return self.topology.default_store_tier
        if B.HOST_TIER in self.tiers:
            return B.HOST_TIER
        for name in self.spill_order:
            if not isinstance(self._tier(name).backend, B.DeviceBackend):
                return name
        return self.spill_order[-1]

    @property
    def admission_tiers(self) -> Tuple[str, ...]:
        """Tiers admission control may count a request's worst-case pages
        against (``sched.queue.AdmissionController``) — declared per-spec
        in the topology; for topology-less pools, the historical
        device+host pair (every tier above the last as a fallback)."""
        if self.topology is not None:
            return self.topology.admission_tiers
        legacy = tuple(n for n in self.spill_order
                       if n in (B.DEVICE_TIER, B.HOST_TIER))
        if legacy:
            return legacy
        return tuple(self.spill_order[:-1]) or (self.spill_order[0],)

    # -- storing -------------------------------------------------------
    def put(self, key: str, value, tier: Optional[str] = None, *,
            priority: float = 0.0, pinned: bool = False) -> PoolEntry:
        """Store ``value`` into ``tier`` (default: the pool's
        ``default_store_tier``), evicting (spilling down-hierarchy)
        as needed. Re-putting an existing key replaces it; if the new value
        doesn't fit, the old entry survives untouched."""
        if tier is None:
            tier = self.default_store_tier
        t0 = self.tracer.now() if self.tracer.enabled else 0.0
        with self._lock:
            st = self._tier(tier)
            # on-wire size: what this put moves and occupies at rest. For
            # a codec-wrapped tier this is the *encoded* size — every
            # byte counter downstream (tier occupancy, bytes_stored, the
            # per tier-pair calibration table) must see wire bytes, not
            # the decoded nbytes, or measured bandwidth inflates by the
            # compression ratio.
            nbytes = int(st.backend.wire_nbytes(value))
            old = self.entries.pop(key, None)
            if old is not None:
                self._tier(old.tier).used -= old.nbytes
            try:
                self._make_room(st, nbytes)
            except PoolCapacityError:
                if old is not None:   # restore — a failed put loses nothing
                    self.entries[key] = old
                    self._tier(old.tier).used += old.nbytes
                raise
            t_x = time.perf_counter()
            handle = st.backend.put(value)
            if not isinstance(st.backend, B.DeviceBackend):
                # value arrives device-side; a store into any lower tier is
                # measured d2r traffic the calibration table should see
                self.transfer.record_pair(B.DEVICE_TIER, tier, nbytes,
                                          time.perf_counter() - t_x)
            self._clock += 1
            entry = PoolEntry(key=key, tier=tier, handle=handle,
                              nbytes=nbytes, priority=priority,
                              pinned=pinned, last_use=self._clock)
            self.entries[key] = entry
            st.used += nbytes
            st.peak = max(st.peak, st.used)
            self.stats.puts += 1
            self.stats.bytes_stored += nbytes
            if self.tracer.enabled:
                self.tracer.complete("pool", "put", t0, self.tracer.now() - t0,
                                     {"key": key, "tier": tier,
                                      "nbytes": nbytes})
            return entry

    # -- fetching ------------------------------------------------------
    def get(self, key: str):
        """Materialize the entry on device (synchronous)."""
        t0 = self.tracer.now() if self.tracer.enabled else 0.0
        with self._lock:
            entry = self.entries[key]
            self._clock += 1
            entry.last_use = self._clock
            self.stats.gets += 1
            self.stats.bytes_fetched += entry.nbytes
            backend, handle = self._tier(entry.tier).backend, entry.handle
        t_x = time.perf_counter()
        value = backend.get(handle)
        if not isinstance(backend, B.DeviceBackend):
            self.transfer.record_pair(entry.tier, B.DEVICE_TIER, entry.nbytes,
                                      time.perf_counter() - t_x)
        if self.tracer.enabled:
            self.tracer.complete("pool", "fetch", t0, self.tracer.now() - t0,
                                 {"key": key, "tier": entry.tier,
                                  "nbytes": entry.nbytes})
        return value

    def prefetch(self, key: str) -> TransferHandle:
        """Issue an async device fetch through the transfer engine; the
        returned handle's ``wait()`` yields the device array. The source
        tier rides along as trace metadata (per-tier-pair overlap)."""
        with self._lock:
            entry = self.entries[key]   # fail fast on unknown keys
            backend, handle = self._tier(entry.tier).backend, entry.handle
            src = entry.tier

        def fetch():
            with self._lock:
                self._clock += 1
                entry.last_use = self._clock
                self.stats.gets += 1
                self.stats.bytes_fetched += entry.nbytes
            return backend.get(handle)

        return self.transfer.submit(fetch, key=key, src=src,
                                    dst=B.DEVICE_TIER, nbytes=entry.nbytes)

    # -- bookkeeping ---------------------------------------------------
    def close(self) -> None:
        """Drain and shut down the transfer engine's worker threads."""
        self.transfer.close()

    def drop(self, key: str) -> None:
        with self._lock:
            self._forget(key)
            self.stats.drops += 1

    def pin(self, key: str, pinned: bool = True) -> None:
        with self._lock:
            self.entries[key].pinned = pinned

    def set_priority(self, key: str, priority: float) -> None:
        """Re-rank an entry for eviction without touching its data — the
        scheduler demotes a preempted request's parked pages this way so
        device-tier pressure spills them ahead of live sequences' pages
        (no-op for keys not in the pool)."""
        with self._lock:
            entry = self.entries.get(key)
            if entry is not None:
                entry.priority = priority

    # -- admission control (capacity reservation) ----------------------
    def reserve(self, key: str, nbytes: int,
                tiers: Optional[Sequence[str]] = None,
                covers: Optional[str] = None,
                itemsize: Optional[int] = None) -> bool:
        """Reserve ``nbytes`` of worst-case capacity against the combined
        byte budget of ``tiers`` (default: every tier). This is the serving
        scheduler's admission-control ledger: a request is admitted only if
        its worst-case KV pages fit alongside current occupancy plus every
        standing reservation. Reservations are bookkeeping only — they never
        block ``put`` (puts spill down-tier by design) — but a put made
        under a reservation is guaranteed a home in the reserved tiers.

        ``covers`` names a key prefix whose entries this reservation pays
        for: their occupancy is excluded from the capacity check (they are
        bounded by — and already charged as — the reservation), so a
        running request's parked pages aren't double-counted against new
        admissions.

        ``nbytes`` is always the *decoded* (full-precision) worst case;
        ``itemsize`` tells the ledger the decoded element size so
        codec-wrapped tiers are counted at their decoded-equivalent
        capacity (a tier storing int8 payloads of fp32 pages effectively
        holds 4× the decoded bytes its raw capacity suggests). Without it
        the check is raw-byte (historical) and under-admits when a codec
        is active.

        Returns False (and records nothing) if it doesn't fit; re-reserving
        an existing key replaces it. A tier with unbounded capacity makes
        the reservation always succeed."""
        with self._lock:
            tiers = tuple(tiers) if tiers is not None else tuple(self.spill_order)
            old = self._reservations.pop(key, None)
            cap, used, unbounded = self._capacity_used(tiers, itemsize)
            if not unbounded:
                held = sum(n for n, ts, _ in self._reservations.values()
                           if set(ts) & set(tiers))
                if used + held + int(nbytes) > cap:
                    if old is not None:
                        self._reservations[key] = old
                    return False
            self._reservations[key] = (int(nbytes), tiers, covers)
            return True

    def release(self, key: str) -> None:
        """Drop a reservation (no-op if absent)."""
        with self._lock:
            self._reservations.pop(key, None)

    def reserved_bytes(self, tiers: Optional[Sequence[str]] = None) -> int:
        with self._lock:
            if tiers is None:
                return sum(n for n, _, _ in self._reservations.values())
            want = set(tiers)
            return sum(n for n, ts, _ in self._reservations.values()
                       if set(ts) & want)

    def headroom(self, tiers: Sequence[str],
                 itemsize: Optional[int] = None) -> Optional[int]:
        """Free *decoded-equivalent* bytes across ``tiers`` after occupancy
        (reservation-covered entries excluded) and standing reservations
        (None = unbounded). ``itemsize`` as in :meth:`reserve`."""
        with self._lock:
            cap, used, unbounded = self._capacity_used(tiers, itemsize)
            if unbounded:
                return None
            return cap - used - self.reserved_bytes(tiers)

    def tier_scale(self, name: str, itemsize: Optional[int]) -> float:
        """On-wire bytes per decoded byte for entries at rest in ``name``
        (< 1 on a codec-wrapped tier). ``None`` itemsize → 1.0, the
        historical raw-byte accounting."""
        if itemsize is None:
            return 1.0
        b = self._tier(name).backend
        if isinstance(b, B.CodecBackend):
            return b.codec.ratio(int(itemsize))
        return 1.0

    def _capacity_used(self, tiers: Sequence[str],
                       itemsize: Optional[int] = None) -> Tuple[int, int, bool]:
        """(capacity, occupancy-net-of-covered-entries, any-unbounded)
        across ``tiers``, in decoded-equivalent bytes when ``itemsize``
        is given (each codec tier's capacity and occupancy are divided by
        its wire/decoded ratio before summing — per tier, because the
        ratio differs tier to tier). Covered entries (key under a
        reservation's ``covers`` prefix) are bounded by their reservation,
        which the caller charges separately."""
        cap = used = 0.0
        unbounded = False
        names = set(tiers)
        prefixes = tuple(c for _, ts, c in self._reservations.values()
                         if c is not None and set(ts) & names)
        for t in tiers:
            st = self._tier(t)
            if st.capacity is None:
                unbounded = True
                continue
            scale = self.tier_scale(t, itemsize)
            tier_used = st.used
            if prefixes:
                tier_used -= sum(e.nbytes for e in self.entries.values()
                                 if e.tier == t and e.key.startswith(prefixes))
            cap += st.capacity / scale
            used += tier_used / scale
        # floor capacity / ceil occupancy: rounding never over-admits
        return int(math.floor(cap)), int(math.ceil(used)), unbounded

    # -- eviction notification -----------------------------------------
    def add_evict_listener(self, cb: Callable[[PoolEntry, str], None]) -> None:
        """Register ``cb(entry, dst_tier)``, called after an entry spills
        down-hierarchy. Called under the pool lock — keep it cheap and
        don't block (pool methods are safe to call: the lock is reentrant)."""
        with self._lock:
            self._evict_listeners.append(cb)

    def remove_evict_listener(self, cb: Callable[[PoolEntry, str], None]) -> None:
        """Unregister a listener (no-op if absent) — callers sharing a
        long-lived pool must remove themselves on shutdown."""
        with self._lock:
            if cb in self._evict_listeners:
                self._evict_listeners.remove(cb)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def tier_of(self, key: str) -> str:
        return self.entries[key].tier

    def is_host_resident(self, key: str) -> bool:
        """The entry lives off-device AND its handle checks out where its
        tier claims (device-tier entries are never 'host resident')."""
        entry = self.entries[key]
        st = self._tier(entry.tier)
        return (not isinstance(st.backend, B.DeviceBackend)
                and st.backend.holds(entry.handle))

    def occupancy(self, tier: str) -> Tuple[int, Optional[int]]:
        st = self._tier(tier)
        return st.used, st.capacity

    def snapshot(self) -> Dict[str, Any]:
        """Stats + per-tier occupancy, for benchmarks/serving to print."""
        with self._lock:
            out: Dict[str, Any] = self.stats.snapshot()
            out["transfer"] = self.transfer.stats.snapshot()
            out["reserved"] = self.reserved_bytes()
            for name, st in self.tiers.items():
                out[f"tier/{name}"] = {
                    "backend": st.backend.name, "used": st.used,
                    "peak": st.peak, "capacity": st.capacity,
                    "entries": sum(1 for e in self.entries.values()
                                   if e.tier == name),
                }
            return out

    # -- internals -----------------------------------------------------
    def _tier(self, name: str) -> TierState:
        try:
            return self.tiers[name]
        except KeyError:
            raise KeyError(f"unknown tier {name!r}; have {list(self.tiers)}")

    def _forget(self, key: str) -> None:
        entry = self.entries.pop(key)
        self._tier(entry.tier).used -= entry.nbytes

    def _next_tier(self, name: str) -> Optional[str]:
        i = self.spill_order.index(name)
        return self.spill_order[i + 1] if i + 1 < len(self.spill_order) else None

    def _make_room(self, st: TierState, nbytes: int) -> None:
        while not st.room_for(nbytes):
            victim = self._pick_victim(st.name)
            if victim is None:
                raise PoolCapacityError(
                    f"tier {st.name!r}: need {nbytes} bytes, "
                    f"{st.used}/{st.capacity} used, nothing evictable")
            self._evict(victim)

    def _pick_victim(self, tier: str) -> Optional[PoolEntry]:
        candidates = [e for e in self.entries.values()
                      if e.tier == tier and not e.pinned]
        if not candidates:
            return None
        # lowest planner priority first; LRU breaks ties
        return min(candidates, key=lambda e: (e.priority, e.last_use))

    def _evict(self, entry: PoolEntry) -> None:
        """Spill one entry to the next tier down (or fail at the bottom)."""
        dst = self._next_tier(entry.tier)
        if dst is None:
            raise PoolCapacityError(
                f"cannot evict {entry.key!r}: {entry.tier!r} is the last tier")
        src_st, dst_st = self._tier(entry.tier), self._tier(dst)
        # the entry's at-rest size may change across the boundary: a spill
        # into a codec tier quantizes (fewer wire bytes), a spill between
        # two codec tiers moves the payload as-is. What actually crosses
        # the link is the destination's wire size.
        new_nbytes = int(dst_st.backend.wire_nbytes(entry.handle))
        self._make_room(dst_st, new_nbytes)
        t_x = time.perf_counter()
        entry.handle = dst_st.backend.put(entry.handle)
        self.transfer.record_pair(src_st.name, dst, new_nbytes,
                                  time.perf_counter() - t_x)
        src_st.used -= entry.nbytes
        dst_st.used += new_nbytes
        dst_st.peak = max(dst_st.peak, dst_st.used)
        entry.tier = dst
        entry.nbytes = new_nbytes
        self.stats.evictions += 1
        self.stats.bytes_evicted += new_nbytes
        if self.tracer.enabled:
            self.tracer.instant("pool", "spill",
                                {"key": entry.key, "src": src_st.name,
                                 "dst": dst, "nbytes": new_nbytes})
        for cb in self._evict_listeners:
            cb(entry, dst)


# ---------------------------------------------------------------------------


def default_pool(host_capacity: Optional[int] = None,
                 remote_capacity: Optional[int] = None,
                 device_capacity: Optional[int] = None,
                 device=None,
                 transfer: Optional[TransferEngine] = None, *,
                 topology: Optional[TierTopology] = None,
                 transfer_depth: Optional[int] = None,
                 transfer_workers: int = 2,
                 codec: Optional[str] = None,
                 codec_below: Optional[str] = None,
                 tracer=None) -> MemoryPoolManager:
    """Build a pool from a declarative ``TierTopology`` — by default the
    standard three-tier chain: device HBM → host → modeled remote
    (unthrottled, i.e. the historical simulated-remote behavior).

    Capacities may be passed either through the legacy per-tier kwargs (the
    default chain only) or inside an explicit ``topology``'s specs — never
    both.

    ``codec`` names a KV page codec (``"int8"``/``"fp8"``; ``None``/
    ``"none"`` disables). Every tier from ``codec_below`` (default: the
    topology's default store tier) down to the bottom of the chain gets its
    backend wrapped in a :class:`~repro.pool.backend.CodecBackend`, so
    pages quantize once on first arrival below the boundary and spills
    deeper down move the compact payload as-is. Spills only ever descend,
    so an encoded page can never land in an unwrapped tier. The boundary
    must not be an accelerator tier — the compute path needs full-precision
    pages on device.

    ``transfer_depth``/``transfer_workers`` build the engine here so callers
    outside the pool subsystem never construct a ``TransferEngine`` — depth
    comes from ``transfer.auto_depth`` (or ``OffloadConfig``)."""
    if topology is None:
        topology = TierTopology.default(device_capacity=device_capacity,
                                        host_capacity=host_capacity,
                                        remote_capacity=remote_capacity)
    elif any(c is not None for c in (host_capacity, remote_capacity,
                                     device_capacity)):
        raise ValueError(
            "pass capacities inside the topology's TierSpecs, not alongside "
            "an explicit topology")
    if transfer is None and transfer_depth is not None:
        transfer = TransferEngine(depth=transfer_depth, workers=transfer_workers)
    codec_obj = codec_mod.make_codec(codec)
    boundary = codec_below if codec_below is not None \
        else topology.default_store_tier
    if codec_obj is not None and boundary not in topology.names:
        raise ValueError(
            f"kv_codec boundary tier {boundary!r} not in topology "
            f"{list(topology.names)}")
    tiers = []
    below = False
    for s in topology.tiers:
        b = B.backend_for(s, device)
        if codec_obj is not None:
            if s.name == boundary:
                below = True
            if below:
                if isinstance(b, B.DeviceBackend):
                    raise ValueError(
                        f"kv_codec boundary {boundary!r} would wrap "
                        f"accelerator tier {s.name!r}; pick an "
                        "off-accelerator tier")
                b = B.CodecBackend(b, codec_obj)
        tiers.append(TierState(s.name, b, s.capacity))
    return MemoryPoolManager(tiers, transfer=transfer, tracer=tracer,
                             topology=topology)
