"""Declarative tier topology: the memory hierarchy as data, not literals.

The pool used to be three hard-coded tier strings (device → host → remote)
threaded through every subsystem. A ``TierTopology`` makes the chain a
first-class, ordered description — each ``TierSpec`` names one tier, its
storage backend kind, its capacity, whether admission control may count it,
and (for ``modeled`` tiers) the latency/bandwidth the backend *enforces* by
sleep-throttling each transfer. This is what lets the remote tier stop
being an unannotated NumPy stand-in: a modeled disaggregated tier has a
real transfer character the runtime feels and the telemetry measures, and
it is sweepable (the paper's Fig. 6 D2H bandwidth sweep) by constructing
topologies across a bandwidth grid.

``TierTopology.default()`` reproduces the historical device/host/remote
chain exactly: same names, same backends for device and host, same
admission set (device + host), and an *unthrottled* modeled tier in the
remote slot whose storage is the same NumPy buffers as before.

Specs are frozen and hashable — a topology participates in plan-cache keys
(``sched.prefetch``) so plans computed under different hierarchies never
alias.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterator, Mapping, Optional, Sequence, Tuple

TIER_KINDS = ("device", "host", "numpy", "modeled")


@dataclass(frozen=True)
class TierSpec:
    """One tier in the chain.

    ``kind`` selects the storage backend (``pool.backend.backend_for``):

    - ``device``  — accelerator HBM (must be the chain's first tier);
    - ``host``    — best host memory this platform supports (memory-kind
      sharding, degrading to NumPy);
    - ``numpy``   — plain NumPy host buffers;
    - ``modeled`` — NumPy storage behind a sleep-throttle that enforces
      ``read_bw``/``write_bw`` (bytes/s, None → unthrottled) plus
      ``read_latency_s``/``write_latency_s`` per transfer. The only kind
      the throttle fields are valid for.

    ``capacity`` is the tier's byte budget (None → unbounded), ``admit``
    marks it countable by admission control (``sched.queue``).
    """

    name: str
    kind: str = "modeled"
    capacity: Optional[int] = None
    admit: bool = True
    read_bw: Optional[float] = None        # tier → device, bytes/s
    write_bw: Optional[float] = None       # device → tier, bytes/s
    read_latency_s: float = 0.0
    write_latency_s: float = 0.0

    def __post_init__(self) -> None:
        if not self.name or not isinstance(self.name, str):
            raise ValueError("TierSpec.name must be a non-empty string")
        if self.kind not in TIER_KINDS:
            raise ValueError(
                f"TierSpec.kind must be one of {TIER_KINDS}, got {self.kind!r}")
        if self.capacity is not None and self.capacity < 0:
            raise ValueError("TierSpec.capacity must be >= 0 or None")
        for bw_name in ("read_bw", "write_bw"):
            bw = getattr(self, bw_name)
            if bw is not None and bw <= 0:
                raise ValueError(f"TierSpec.{bw_name} must be > 0 or None")
        for lat_name in ("read_latency_s", "write_latency_s"):
            if getattr(self, lat_name) < 0:
                raise ValueError(f"TierSpec.{lat_name} must be >= 0")
        if self.kind != "modeled" and self.throttled:
            raise ValueError(
                f"tier {self.name!r}: latency/bandwidth fields are only "
                f"valid for kind='modeled' (got kind={self.kind!r} — real "
                "backends have whatever character the hardware gives them)")

    @property
    def throttled(self) -> bool:
        return (self.read_bw is not None or self.write_bw is not None
                or self.read_latency_s > 0 or self.write_latency_s > 0)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TierSpec":
        unknown = set(d) - {f for f in cls.__dataclass_fields__}
        if unknown:
            raise ValueError(f"unknown TierSpec keys: {sorted(unknown)}")
        return cls(**dict(d))


@dataclass(frozen=True)
class TierTopology:
    """An ordered spill chain of ``TierSpec``s, top (fastest) first.

    Invariants: at least one tier; unique names; a ``device``-kind tier, if
    present, is the first (spill-down only moves away from the
    accelerator); at least one tier admits.
    """

    tiers: Tuple[TierSpec, ...] = field(default_factory=tuple)

    def __post_init__(self) -> None:
        tiers = tuple(self.tiers)
        object.__setattr__(self, "tiers", tiers)
        if not tiers:
            raise ValueError("TierTopology needs at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in topology: {names}")
        for i, t in enumerate(tiers):
            if t.kind == "device" and i != 0:
                raise ValueError(
                    f"device-kind tier {t.name!r} must be the chain's first "
                    "tier (spill-down moves away from the accelerator)")
        if not any(t.admit for t in tiers):
            raise ValueError("at least one tier must admit")

    @classmethod
    def default(cls, *, device_capacity: Optional[int] = None,
                host_capacity: Optional[int] = None,
                remote_capacity: Optional[int] = None) -> "TierTopology":
        """The historical three-tier chain: device → host → remote, with
        admission counting device + host and an unthrottled modeled tier
        (NumPy storage, no latency/bandwidth character) in the remote
        slot — behaviorally identical to the pre-topology pool."""
        return cls(tiers=(
            TierSpec("device", kind="device", capacity=device_capacity),
            TierSpec("host", kind="host", capacity=host_capacity),
            TierSpec("remote", kind="modeled", capacity=remote_capacity,
                     admit=False),
        ))

    # ------------------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    @property
    def top(self) -> str:
        """The chain's fastest tier — where pages are parked for compute."""
        return self.tiers[0].name

    @property
    def default_store_tier(self) -> str:
        """Where ``pool.put`` lands when the caller names no tier: the
        first tier *below* the top (classic offload target), or the only
        tier of a single-tier chain."""
        return self.tiers[1].name if len(self.tiers) > 1 else self.tiers[0].name

    @property
    def admission_tiers(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers if t.admit)

    def spec(self, name: str) -> TierSpec:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"no tier named {name!r} in topology {self.names}")

    def __iter__(self) -> Iterator[TierSpec]:
        return iter(self.tiers)

    def __len__(self) -> int:
        return len(self.tiers)

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"tiers": [t.to_dict() for t in self.tiers]}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TierTopology":
        unknown = set(d) - {"tiers"}
        if unknown:
            raise ValueError(f"unknown TierTopology keys: {sorted(unknown)}")
        specs = d.get("tiers", ())
        return cls(tiers=tuple(
            s if isinstance(s, TierSpec) else TierSpec.from_dict(s)
            for s in specs))


def sweep_topologies(base: TierTopology, tier: str, *,
                     read_bws: Sequence[float]) -> Tuple[TierTopology, ...]:
    """Fig.-6-style bandwidth sweep: one topology per grid point, varying
    ``tier``'s read bandwidth (the tier must be ``modeled``)."""
    spec = base.spec(tier)
    if spec.kind != "modeled":
        raise ValueError(f"can only sweep a modeled tier, {tier!r} is "
                         f"{spec.kind!r}")
    out = []
    for bw in read_bws:
        tiers = tuple(
            TierSpec(**{**t.to_dict(), "read_bw": float(bw)})
            if t.name == tier else t
            for t in base.tiers)
        out.append(TierTopology(tiers=tiers))
    return tuple(out)
