"""Quantized KV page codecs — a pool-layer concern (ITME's tiered-memory
compression argument, PAPERS.md).

A codec turns a full-precision KV page into a compact on-wire payload plus
a per-page scale, so every transfer below the configured tier boundary
(device→host puts, host→remote spills, and the fetches back) moves 2–4×
fewer bytes. Encoding happens exactly once per put — ``pool.backend``
wraps the storage backends of the tiers below the boundary in a
``CodecBackend`` that encodes on ``put`` and decodes on ``get``; a spill
between two encoded tiers moves the *payload* untouched (no
decode/re-encode round trip, and no extra quantization error).

Codecs:

- ``none`` — identity (no wrapping happens; pages move full precision);
- ``int8`` — symmetric per-page absmax quantization: ``scale =
  absmax/127``, payload ``round(x/scale)`` clipped to [-127, 127]. The
  worst-case round-trip error is ``scale/2`` per element — the hard
  numeric bound the test gate asserts;
- ``fp8``  — ``float8_e4m3fn`` payload with a per-page scale mapping the
  page's absmax onto the format's max normal (448), so the full dynamic
  range is spent on the page's actual values. Relative error is bounded
  by the format's epsilon (2^-3) plus the scale rounding.

Scales are kept as host floats riding inside the handle (4 bytes per page
against a multi-KB payload — charged in the on-wire byte accounting, but
negligible); payloads are stored through the wrapped tier's own backend,
so a modeled tier's sleep-throttle and the transfer telemetry both see
the *encoded* byte counts.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CODECS = ("none", "int8", "fp8")

#: float8_e4m3fn max normal — the target of the per-page scale
_FP8_MAX = 448.0


@dataclasses.dataclass
class EncodedPage:
    """One encoded page: the codec's opaque handle.

    ``payload`` is whatever the wrapped tier's backend returned for the
    quantized bytes (jax host array, NumPy buffer, …); ``nbytes`` is the
    on-wire size (payload + scale) that every pool/transfer counter and
    the modeled-tier throttle charge."""

    codec: str
    payload: Any
    scale: float
    dtype: str            # decoded dtype name
    shape: Tuple[int, ...]
    nbytes: int


@functools.partial(jax.jit, static_argnames=("out_dtype",))
def _enc_int8(x, out_dtype=jnp.int8):
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127.0, 127.0)
    return q.astype(out_dtype), scale


@functools.partial(jax.jit, static_argnames=("dtype",))
def _dec_int8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


@jax.jit
def _enc_fp8(x):
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(absmax > 0, absmax / _FP8_MAX, 1.0)
    return (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn), scale


@functools.partial(jax.jit, static_argnames=("dtype",))
def _dec_fp8(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


class KVCodec:
    """One quantization scheme: device array ↔ (1-byte payload, scale)."""

    name: str = "abstract"
    payload_itemsize: int = 1

    def encode(self, value) -> Tuple[jax.Array, float]:
        raise NotImplementedError

    def decode(self, payload, scale: float, dtype: str) -> jax.Array:
        raise NotImplementedError

    def ratio(self, itemsize: int) -> float:
        """On-wire bytes per decoded byte (< 1 compresses). The per-page
        scale is excluded — 4 bytes against a whole page — so capacity
        conversions stay simple; the exact per-page figure lives in
        ``encoded_nbytes``."""
        return self.payload_itemsize / float(itemsize)

    def encoded_nbytes(self, shape, dtype) -> int:
        """Exact on-wire size of one encoded page (payload + scale)."""
        n = 1
        for d in shape:
            n *= int(d)
        return n * self.payload_itemsize + 4

    def __repr__(self) -> str:
        return f"KVCodec({self.name})"


class Int8Codec(KVCodec):
    name = "int8"

    def encode(self, value):
        q, scale = _enc_int8(jnp.asarray(value))
        return q, float(scale)

    def decode(self, payload, scale, dtype):
        return _dec_int8(jnp.asarray(payload), jnp.float32(scale),
                         jnp.dtype(dtype))


class Fp8Codec(KVCodec):
    name = "fp8"

    def encode(self, value):
        q, scale = _enc_fp8(jnp.asarray(value))
        return q, float(scale)

    def decode(self, payload, scale, dtype):
        return _dec_fp8(jnp.asarray(payload), jnp.float32(scale),
                        jnp.dtype(dtype))


def make_codec(name: Optional[str]) -> Optional[KVCodec]:
    """Codec instance by name; ``None``/``"none"`` → no codec (identity
    pages, no backend wrapping)."""
    if name is None or name == "none":
        return None
    if name == "int8":
        return Int8Codec()
    if name == "fp8":
        return Fp8Codec()
    raise ValueError(f"unknown KV codec {name!r}; have {CODECS}")


def roundtrip_bound(codec: KVCodec, absmax: float) -> float:
    """Hard per-element round-trip error bound for a page with the given
    absmax — what the codec test gate asserts against.

    int8: half a quantization step (``scale/2`` = absmax/254).
    fp8 (e4m3): relative error ≤ 2^-4 of the element after scaling, so
    ``absmax * 2^-4`` bounds any element (coarse but hard)."""
    if codec.name == "int8":
        return absmax / 254.0 + 1e-7
    if codec.name == "fp8":
        return absmax / 16.0 + 1e-7
    raise ValueError(f"no round-trip bound for codec {codec.name!r}")


def numpy_supports_fp8() -> bool:
    """ml_dtypes-backed NumPy float8 support (jax always ships ml_dtypes,
    but probe anyway so a missing build degrades loudly at config time
    instead of deep inside a spill)."""
    try:
        np.zeros(1, dtype=jnp.float8_e4m3fn)
        return True
    except Exception:
        return False
