"""Batched serving engine over the model's decode path.

Modes:
- resident (default): the KV cache stays in device memory — the paper's
  inference baseline.
- ``offload_kv=True``: between decode steps the cache is parked in host
  (remote-pool) memory and fetched back on entry — the whole-cache
  Store/Prefetch round trip. On real hardware the fetch overlaps the
  embedding/projection work per the compiler plan; here we validate
  semantics and count traffic. (The page-granular sparse path lives in
  offload.kvcache.PagedKVCache and examples/serve_offload.py.)

Batching: one uniform-length prompt batch per generate() call (bucketed
batching; ragged prompts are padded upstream by the caller).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.offload.optstate import device_fetch_state, host_offload_state
from repro.serving.sampling import sample_token


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_round_trips: int = 0


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, max_seq: int,
                 cache_dtype=jnp.float32, offload_kv: bool = False) -> None:
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.offload_kv = offload_kv
        self.stats = ServeStats()
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, jax.Array], max_new_tokens: int, *,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0) -> jax.Array:
        """batch["tokens"]: (B, S_prompt) int32 → generated ids
        (B, max_new_tokens)."""
        tokens = batch["tokens"]
        b, s0 = tokens.shape
        assert s0 + max_new_tokens <= self.max_seq, "exceeds cache capacity"
        cache = self.model.init_cache(b, self.max_seq, self.cache_dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        self.stats.prefill_tokens += b * s0

        key = jax.random.key(seed)
        out = []
        tok = sample_token(logits[:, 0], key, temperature=temperature, top_k=top_k)
        out.append(tok)
        for i in range(1, max_new_tokens):
            pos = jnp.int32(s0 + i - 1)
            if self.offload_kv:
                cache = host_offload_state(cache)       # Store
                cache = device_fetch_state(cache)       # Prefetch (next step)
                self.stats.cache_round_trips += 1
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            tok = sample_token(logits[:, 0], sub, temperature=temperature,
                               top_k=top_k)
            out.append(tok)
            self.stats.decoded_tokens += b
        return jnp.stack(out, axis=1)
