"""Batched serving engine over the model's decode path.

Modes:
- resident (default): the KV cache stays in device memory — the paper's
  inference baseline.
- ``offload_kv=True``: between decode steps the cache is parked in the
  memory pool's host tier and prefetched back through the async transfer
  engine — the whole-cache Store/Prefetch round trip, with per-leaf
  capacity accounting and traffic stats from the ``MemoryPoolManager``.
  On real hardware the fetch overlaps the embedding/projection work per
  the compiler plan; here we validate semantics and count traffic. (The
  page-granular sparse path lives in offload.kvcache.PagedKVCache and
  examples/serve_offload.py.)

Batching: one uniform-length prompt batch per generate() call (bucketed
batching; ragged prompts are padded upstream by the caller).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.obs import NULL_TRACER
from repro.pool import MemoryPoolManager, auto_depth
from repro.serving.sampling import sample_token


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    cache_round_trips: int = 0


# per-engine pool-key namespace: engines sharing one pool never collide
_ENGINE_IDS = itertools.count()


# Jitted model entry points are shared across engine/scheduler instances
# (keyed by the hashable frozen Model): a second engine over the same model
# reuses the first one's compiled executables instead of re-tracing. The
# cache is bounded so a process sweeping many model variants doesn't pin
# every dead model's executables forever.
@functools.lru_cache(maxsize=64)
def jit_prefill(model: Model):
    return jax.jit(model.prefill)


@functools.lru_cache(maxsize=64)
def jit_decode(model: Model):
    return jax.jit(model.decode_step, donate_argnums=(1,))


@functools.lru_cache(maxsize=64)
def jit_prefill_chunk(model: Model):
    """Chunked-prefill entry point (`Model.prefill_chunk`): the chunk's
    token shape is fixed at (1, chunk_size) and the position offset /
    valid length are traced scalars, so mixed-length traffic compiles
    exactly ONE executable per chunk size — the structural fix for the
    per-prompt-length compile churn of whole-prompt prefill. The row cache
    is donated: chunk i+1 reuses chunk i's buffers. Compile count is
    observable via ``jit_prefill_chunk(model)._cache_size()`` (asserted by
    the serving benchmark)."""
    return jax.jit(model.prefill_chunk, donate_argnums=(4,))


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, max_seq: int,
                 cache_dtype=jnp.float32, offload_kv: bool = False,
                 pool: Optional[MemoryPoolManager] = None,
                 tracer=None) -> None:
        self.model = model
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.params = params
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.offload_kv = offload_kv
        # auto depth policy: one whole cache's leaves issue before any
        # wait (2 K/V leaves per layer plus headroom)
        depth = auto_depth(
            layers=getattr(getattr(model, "cfg", None), "n_layers", 16))
        if offload_kv and pool is None:
            raise ValueError(
                "ServeEngine(offload_kv=True) requires a pool; construct "
                "engines through repro.api.HyperOffloadSession.serve_engine "
                "(mode='kv_offload')")
        if offload_kv:
            # shared (session) pool: declare this consumer's depth need
            pool.transfer.ensure_depth(depth)
        self.pool = pool
        self._key_ns = f"serve{next(_ENGINE_IDS)}"
        self._kv_keys: list = []     # stable per-leaf pool keys, grown on demand
        self._closed = False
        self.stats = ServeStats()
        self._prefill = jit_prefill(model)
        self._decode = jit_decode(model)

    def pool_stats(self) -> Optional[Dict[str, Any]]:
        """Pool traffic/occupancy snapshot (None when serving resident)."""
        return self.pool.snapshot() if self.pool is not None else None

    def close(self) -> None:
        """Mark the engine closed. The pool is always caller-provided
        (session-owned) and is the caller's to close. Idempotent — safe to
        call from both user code and a finalizer."""
        if self._closed:
            return
        self._closed = True

    # ------------------------------------------------------------------
    def _cache_round_trip(self, cache: Any) -> Any:
        """Store every cache leaf into the pool, then prefetch them all
        back through the transfer engine (fetches issue before any wait).
        Leaf keys are stable across steps — a re-``put`` replaces the old
        entry in place, so the decode loop causes zero key churn (no
        put/drop pairs, no LRU-clock noise from dropped entries)."""
        with self.tracer.span("serve", "cache_round_trip",
                              engine=self._key_ns):
            leaves, treedef = jax.tree.flatten(cache)
            while len(self._kv_keys) < len(leaves):
                self._kv_keys.append(f"{self._key_ns}/kv{len(self._kv_keys)}")
            keys = self._kv_keys[:len(leaves)]
            for k, leaf in zip(keys, leaves):
                self.pool.put(k, leaf)   # topology's default store tier
            handles = [self.pool.prefetch(k) for k in keys]
            self.stats.cache_round_trips += 1
            fetched = [h.wait() for h in handles]
            return jax.tree.unflatten(treedef, fetched)

    def _release_cache_keys(self) -> None:
        """Drop the standing cache entries (end of a generate call — the
        host copies are only meaningful while their cache is live)."""
        for k in self._kv_keys:
            if k in self.pool:
                self.pool.drop(k)

    def generate(self, batch: Dict[str, jax.Array], max_new_tokens: int, *,
                 temperature: float = 0.0, top_k: Optional[int] = None,
                 seed: int = 0) -> jax.Array:
        """batch["tokens"]: (B, S_prompt) int32 → generated ids
        (B, max_new_tokens)."""
        tokens = batch["tokens"]
        b, s0 = tokens.shape
        assert s0 + max_new_tokens <= self.max_seq, "exceeds cache capacity"
        with self.tracer.span("serve", "generate", engine=self._key_ns,
                              batch=b, prompt_len=s0,
                              max_new_tokens=max_new_tokens):
            return self._generate(batch, max_new_tokens,
                                  temperature=temperature, top_k=top_k,
                                  seed=seed)

    def _generate(self, batch: Dict[str, jax.Array], max_new_tokens: int, *,
                  temperature: float, top_k: Optional[int],
                  seed: int) -> jax.Array:
        tokens = batch["tokens"]
        b, s0 = tokens.shape
        cache = self.model.init_cache(b, self.max_seq, self.cache_dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        self.stats.prefill_tokens += b * s0

        key = jax.random.key(seed)
        out = []
        tok = sample_token(logits[:, 0], key, temperature=temperature, top_k=top_k)
        out.append(tok)
        try:
            for i in range(1, max_new_tokens):
                pos = jnp.int32(s0 + i - 1)
                if self.offload_kv:
                    cache = self._cache_round_trip(cache)   # Store + Prefetch
                key, sub = jax.random.split(key)
                logits, cache = self._decode(self.params, cache, tok[:, None], pos)
                tok = sample_token(logits[:, 0], sub, temperature=temperature,
                                   top_k=top_k)
                out.append(tok)
                self.stats.decoded_tokens += b
        finally:
            # even on an interrupted decode, standing cache entries must not
            # haunt a shared pool as phantom occupancy
            if self.offload_kv:
                self._release_cache_keys()
        return jnp.stack(out, axis=1)
