"""Token sampling: greedy / temperature / top-k."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_token(logits: jax.Array, key: Optional[jax.Array] = None, *,
                 temperature: float = 0.0, top_k: Optional[int] = None) -> jax.Array:
    """logits (B, V) -> token ids (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None
    lg = logits.astype(jnp.float32) / temperature
    if top_k is not None:
        vals, _ = jax.lax.top_k(lg, top_k)
        cutoff = vals[..., -1:]
        lg = jnp.where(lg < cutoff, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
