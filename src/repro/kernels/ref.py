"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests sweep against
(tests/test_kernels.py: shapes × dtypes × flags, assert_allclose).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def _softcap(x, cap):
    return x if cap is None else cap * jnp.tanh(x / cap)


def flash_attention_ref(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, T, D)
    v: jax.Array,   # (B, Hkv, T, D)
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d) * scale
    kf = k.astype(jnp.float32)
    sc = jnp.einsum("bkgsd,bktd->bkgst", qf, kf)
    sc = _softcap(sc, logit_cap)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,      # (B, Hq, D) — one token per sequence
    k: jax.Array,      # (B, Hkv, C, D) ring cache
    v: jax.Array,      # (B, Hkv, C, D)
    pos: jax.Array,    # scalar int32 — token index just written
    *,
    scale: float,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Attention of one query over a ring-buffer cache: slot j holds token
    t_j = pos - ((pos - j) mod C); valid iff t_j >= 0."""
    b, hq, d = q.shape
    hkv, c = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    sc = jnp.einsum("bkgd,bkcd->bkgc", qf, k.astype(jnp.float32))
    sc = _softcap(sc, logit_cap)
    j = jnp.arange(c)
    tj = pos - jnp.mod(pos - j, c)
    sc = jnp.where((tj >= 0)[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgc,bkcd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,           # (B, Hq, D) — one token per sequence
    k_pages: jax.Array,     # (P, B, page, Hkv, D) — page-resident slots
    v_pages: jax.Array,
    page_table: jax.Array,  # (n,) int — slots to attend over, in order
    k_tail: jax.Array,      # (B, page, Hkv, D) — device tail buffer
    v_tail: jax.Array,
    tail_len: jax.Array,    # scalar int — valid tokens in the tail
    *,
    scale: float,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Decode attention over non-contiguous pages + device tail.

    The lowering-free oracle for ``kernels.paged_attention``'s
    paged-decode kernel: gathers ``k_pages[page_table]`` and then runs
    *exactly* the two-segment merged-softmax math of
    ``offload.kvcache._paged_attend`` (scores per segment, tail mask at
    ``tail_len``, one concatenated softmax) — with ``logit_cap=None``
    the output is bit-for-bit the gather path's, which is what makes
    codec-"none" serving token-identical when the fused path replaces
    the per-step gather/concat round trip."""
    b, hq, d = q.shape
    page, hkv = k_tail.shape[1], k_tail.shape[2]
    g = hq // hkv
    kp = k_pages[page_table]                  # (n, B, page, Hkv, D)
    vp = v_pages[page_table]
    n = kp.shape[0]
    k_flat = kp.transpose(1, 0, 2, 3, 4).reshape(b, n * page, hkv, d)
    v_flat = vp.transpose(1, 0, 2, 3, 4).reshape(b, n * page, hkv, d)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    s_pages = jnp.einsum("bkgd,btkd->bkgt", qf,
                         k_flat.astype(jnp.float32)).reshape(b, hq, n * page)
    s_tail = jnp.einsum("bkgd,btkd->bkgt", qf,
                        k_tail.astype(jnp.float32)).reshape(b, hq, page)
    s_pages = _softcap(s_pages, logit_cap)
    s_tail = _softcap(s_tail, logit_cap)
    t_mask = jnp.arange(page) < tail_len
    s_tail = jnp.where(t_mask[None, None, :], s_tail, NEG_INF)
    s = jnp.concatenate([s_pages, s_tail], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    v_all = jnp.concatenate([v_flat, v_tail], axis=1)   # (B, T, Hkv, D)
    pf = p.reshape(b, hkv, g, -1)
    out = jnp.einsum("bkgt,btkd->bkgd", pf, v_all.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,     # (B, S, H, P) pre-scaled by dt
    a: jax.Array,     # (B, S, H) = dt * A (negative)
    b_mat: jax.Array,  # (B, S, H, N)
    c_mat: jax.Array,  # (B, S, H, N)
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD oracle — delegates to the model-substrate implementation
    (itself validated against the O(S) recurrence in tests/test_ssm.py)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, a, b_mat, c_mat, chunk)
