"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests sweep against
(tests/test_kernels.py: shapes × dtypes × flags, assert_allclose).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def _softcap(x, cap):
    return x if cap is None else cap * jnp.tanh(x / cap)


def flash_attention_ref(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, T, D)
    v: jax.Array,   # (B, Hkv, T, D)
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d) * scale
    kf = k.astype(jnp.float32)
    sc = jnp.einsum("bkgsd,bktd->bkgst", qf, kf)
    sc = _softcap(sc, logit_cap)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,      # (B, Hq, D) — one token per sequence
    k: jax.Array,      # (B, Hkv, C, D) ring cache
    v: jax.Array,      # (B, Hkv, C, D)
    pos: jax.Array,    # scalar int32 — token index just written
    *,
    scale: float,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Attention of one query over a ring-buffer cache: slot j holds token
    t_j = pos - ((pos - j) mod C); valid iff t_j >= 0."""
    b, hq, d = q.shape
    hkv, c = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    sc = jnp.einsum("bkgd,bkcd->bkgc", qf, k.astype(jnp.float32))
    sc = _softcap(sc, logit_cap)
    j = jnp.arange(c)
    tj = pos - jnp.mod(pos - j, c)
    sc = jnp.where((tj >= 0)[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgc,bkcd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,     # (B, S, H, P) pre-scaled by dt
    a: jax.Array,     # (B, S, H) = dt * A (negative)
    b_mat: jax.Array,  # (B, S, H, N)
    c_mat: jax.Array,  # (B, S, H, N)
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD oracle — delegates to the model-substrate implementation
    (itself validated against the O(S) recurrence in tests/test_ssm.py)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, a, b_mat, c_mat, chunk)
