"""Decode attention Pallas TPU kernels: ring-buffer cache and paged pool.

**Ring kernel** (``decode_attention_pallas``): one query token per
sequence attends over a contiguous ring cache with online softmax. Grid
(batch·kv_heads, kv_blocks): the GQA query group for a kv head is one q
block of shape (G, D), so the score matmul is (G×D)·(D×bk) on the MXU.
Ring-slot validity (slot j holds token pos−((pos−j) mod C), valid iff ≥ 0)
is computed in the jit wrapper — it depends on the traced ``pos`` — and
streamed to the kernel as a mask, keeping the kernel scalar-free.

**Paged kernel** (``paged_decode_attention_pallas``): the true
HyperOffload §5.2 serving hot path. The request's KV lives as
*non-contiguous* pages in a device page buffer plus a partial tail page;
instead of gathering + concatenating them per decode step (the
``offload.kvcache`` round trip this kernel replaces), the page table rides
in as a **scalar-prefetch operand** and the k/v BlockSpec index maps walk
it: grid step ``ik`` pulls page ``page_table[ik]`` straight from the
paged buffer, the final grid step covers the device tail, and one online
softmax merges all of it — no materialized contiguous copy at any point.
Tail validity (``arange(page) < tail_len``) streams in as a mask row, so
an empty, partial, or just-flushed tail needs no kernel recompile.
``kernels.ref.paged_decode_attention_ref`` is the lowering-free oracle
(and the CPU serving fallback — bit-identical to the legacy gather path).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref,
                   m_scr, l_scr, acc_scr,
                   *, scale: float, logit_cap: Optional[float],
                   n_kv_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
    valid = mask_ref[0]                               # (bk,) bool

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,     # (B, Hq, D)
    k: jax.Array,     # (B, Hkv, C, D)
    v: jax.Array,
    pos: jax.Array,   # scalar int32
    *,
    scale: float,
    logit_cap: Optional[float] = None,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    hkv, c = k.shape[1], k.shape[2]
    g = hq // hkv
    block_k = min(block_k, max(8, c))
    pad_k = (-c) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    ck = c + pad_k
    nk = ck // block_k

    # ring validity mask (see module docstring)
    j = jnp.arange(ck)
    tj = pos - jnp.mod(pos - j, c)
    mask = ((tj >= 0) & (j < c))[None, :]             # (1, ck)

    qg = q.reshape(b, hkv, g, d)
    grid = (b * hkv, nk)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               logit_cap=logit_cap, n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bh, ik: (bh // hkv, bh % hkv, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bh, ik: (bh // hkv, bh % hkv, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bh, ik: (bh // hkv, bh % hkv, ik, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ik: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bh, ik: (bh // hkv, bh % hkv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, mask)
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# paged decode: page-table-driven BlockSpecs over the pool page buffer
# ---------------------------------------------------------------------------


def _paged_decode_kernel(pt_ref, q_ref, k_ref, v_ref, kt_ref, vt_ref,
                         mask_ref, o_ref, m_scr, l_scr, acc_scr,
                         *, scale: float, logit_cap: Optional[float],
                         n_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    # the last grid step is the tail segment; every earlier step is the
    # page the index map prefetched via pt_ref (n_blocks is static, so
    # this select folds per grid position)
    is_tail = ik == n_blocks - 1
    k = jnp.where(is_tail, kt_ref[0, :, 0, :], k_ref[0, 0, :, 0, :])
    v = jnp.where(is_tail, vt_ref[0, :, 0, :], v_ref[0, 0, :, 0, :])
    k = k.astype(jnp.float32)                            # (page, D)
    v = v.astype(jnp.float32)
    valid = mask_ref[0]                                  # (page,) bool

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, page)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ik == n_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,           # (B, Hq, D)
    k_pages: jax.Array,     # (P, B, page, Hkv, D) — page buffer slots
    v_pages: jax.Array,
    page_table: jax.Array,  # (n,) int32 — slots to attend, in order
    k_tail: jax.Array,      # (B, page, Hkv, D)
    v_tail: jax.Array,
    tail_len: jax.Array,    # scalar int32
    *,
    scale: float,
    logit_cap: Optional[float] = None,
    interpret: bool = True,
) -> jax.Array:
    """Fused paged decode: attend directly over the non-contiguous pages
    named by ``page_table`` plus the device tail, in one online-softmax
    pass (see module docstring). The page dimension is the kv block, so
    pool-transfer granularity and kernel tiling coincide."""
    b, hq, d = q.shape
    page, hkv = k_tail.shape[1], k_tail.shape[2]
    g = hq // hkv
    if k_pages.shape[0] == 0:
        # the k/v operands need at least one indexable slot even when the
        # table is empty (tail-only attention); a zero page is never read
        # — no index map ever points at it
        k_pages = jnp.zeros((1,) + k_pages.shape[1:], k_pages.dtype)
        v_pages = jnp.zeros((1,) + v_pages.shape[1:], v_pages.dtype)
    n = int(page_table.shape[0])
    n_blocks = n + 1                                     # pages ++ tail
    # the tail grid step never reads the paged operands, but its index map
    # still runs — park it on slot 0 so the prefetch stays in range
    pt = jnp.concatenate([jnp.asarray(page_table, jnp.int32),
                          jnp.zeros((1,), jnp.int32)])
    mask = jnp.concatenate(
        [jnp.ones((n, page), bool),
         (jnp.arange(page) < tail_len)[None, :]], axis=0)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               logit_cap=logit_cap, n_blocks=n_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bh, ik, pt: (bh // hkv, bh % hkv, 0, 0)),
            pl.BlockSpec((1, 1, page, 1, d),
                         lambda bh, ik, pt: (pt[ik], bh // hkv, 0,
                                             bh % hkv, 0)),
            pl.BlockSpec((1, 1, page, 1, d),
                         lambda bh, ik, pt: (pt[ik], bh // hkv, 0,
                                             bh % hkv, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bh, ik, pt: (bh // hkv, 0, bh % hkv, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda bh, ik, pt: (bh // hkv, 0, bh % hkv, 0)),
            pl.BlockSpec((1, page), lambda bh, ik, pt: (ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bh, ik, pt: (bh // hkv, bh % hkv,
                                                   0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(pt, qg, k_pages, v_pages, k_tail, v_tail, mask)
    return out.reshape(b, hq, d)
