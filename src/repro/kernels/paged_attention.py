"""Decode attention Pallas TPU kernel over a ring-buffer KV cache.

One query token per sequence attends over the cache with online softmax.
Grid (batch·kv_heads, kv_blocks): the GQA query group for a kv head is one
q block of shape (G, D), so the score matmul is (G×D)·(D×bk) on the MXU.
Ring-slot validity (slot j holds token pos−((pos−j) mod C), valid iff ≥ 0)
is computed in the jit wrapper — it depends on the traced ``pos`` — and
streamed to the kernel as a mask, keeping the kernel scalar-free.

This is the HyperOffload serving hot path: when KV blocks are prefetched
from the remote pool (offload.kvcache), this kernel consumes them directly
block-by-block, so the BlockSpec kv tiling doubles as the pool-transfer
granularity.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref,
                   m_scr, l_scr, acc_scr,
                   *, scale: float, logit_cap: Optional[float],
                   n_kv_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
    valid = mask_ref[0]                               # (bk,) bool

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,     # (B, Hq, D)
    k: jax.Array,     # (B, Hkv, C, D)
    v: jax.Array,
    pos: jax.Array,   # scalar int32
    *,
    scale: float,
    logit_cap: Optional[float] = None,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, d = q.shape
    hkv, c = k.shape[1], k.shape[2]
    g = hq // hkv
    block_k = min(block_k, max(8, c))
    pad_k = (-c) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    ck = c + pad_k
    nk = ck // block_k

    # ring validity mask (see module docstring)
    j = jnp.arange(ck)
    tj = pos - jnp.mod(pos - j, c)
    mask = ((tj >= 0) & (j < c))[None, :]             # (1, ck)

    qg = q.reshape(b, hkv, g, d)
    grid = (b * hkv, nk)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               logit_cap=logit_cap, n_kv_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bh, ik: (bh // hkv, bh % hkv, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bh, ik: (bh // hkv, bh % hkv, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bh, ik: (bh // hkv, bh % hkv, ik, 0)),
            pl.BlockSpec((1, block_k), lambda bh, ik: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bh, ik: (bh // hkv, bh % hkv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, mask)
    return out.reshape(b, hq, d)
