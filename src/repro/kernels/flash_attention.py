"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax attention tiled for VMEM: grid (batch·q_heads, q_blocks,
kv_blocks), with the kv dimension innermost so the running max / sum /
accumulator scratch carries across kv iterations (TPU grids iterate
sequentially, minor-to-major). Supports GQA (kv head = q head // group),
causal masking, sliding windows, and gemma2-style logit soft-capping.

Block shapes are MXU-aligned (multiples of 128 on the sequence dims); the
working set per grid step is q(bq×D) + k,v(bk×D) + acc(bq×D) — a few
hundred KiB in VMEM at the default 128/128 tiling.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, window: Optional[int],
                  logit_cap: Optional[float], block_q: int, block_k: int,
                  seq_q: int, seq_k: int, n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)

    qi = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kj < seq_k
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                            # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, Hq, S, D)
    k: jax.Array,   # (B, Hkv, T, D)
    v: jax.Array,
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, max(8, s))
    block_k = min(block_k, max(8, t))

    pad_q = (-s) % block_q
    pad_k = (-t) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq, tk = s + pad_q, t + pad_k
    nq, nk = sq // block_q, tk // block_k

    grid = (b * hq, nq, nk)

    def q_index(bh, iq, ik):
        return (bh // hq, bh % hq, iq, 0)

    def kv_index(bh, iq, ik):
        return (bh // hq, (bh % hq) // g, ik, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        logit_cap=logit_cap, block_q=block_q, block_k=block_k,
        seq_q=s, seq_k=t, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bh, iq, ik: q_index(bh, iq, ik)),
            pl.BlockSpec((1, 1, block_k, d), lambda bh, iq, ik: kv_index(bh, iq, ik)),
            pl.BlockSpec((1, 1, block_k, d), lambda bh, iq, ik: kv_index(bh, iq, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bh, iq, ik: q_index(bh, iq, ik)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :]
