"""Jit'd public wrappers around the Pallas kernels.

On the CPU test/dry-run host the kernels execute in interpret mode; on real
TPU set ``interpret=False`` (the module-level knob) to compile them. The
model substrate calls these through ``repro.models.runtime`` dispatch.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import (
    decode_attention_pallas, paged_decode_attention_pallas,
)
from repro.kernels.ssd_scan import ssd_scan_pallas

# CPU backend executes Pallas in interpret mode only.
INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "window", "logit_cap", "causal"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, window: Optional[int] = None,
                    logit_cap: Optional[float] = None,
                    causal: bool = True) -> jax.Array:
    """Model-layout flash attention: q (B,S,Hq,D), k/v (B,T,Hkv,D) →
    (B,S,Hq,D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, scale=scale, causal=causal,
                                 window=window, logit_cap=logit_cap,
                                 interpret=INTERPRET)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("scale", "logit_cap"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, scale: float,
                     logit_cap: Optional[float] = None) -> jax.Array:
    """Ring-cache decode attention: q (B,1,Hq,D), cache (B,C,Hkv,D) →
    (B,1,Hq,D)."""
    q3 = q[:, 0]                              # (B, Hq, D)
    kt = k.transpose(0, 2, 1, 3)              # (B, Hkv, C, D)
    vt = v.transpose(0, 2, 1, 3)
    out = decode_attention_pallas(q3, kt, vt, pos, scale=scale,
                                  logit_cap=logit_cap, interpret=INTERPRET)
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("scale", "logit_cap"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           k_tail: jax.Array, v_tail: jax.Array,
                           tail_len: jax.Array, *, scale: float,
                           logit_cap: Optional[float] = None) -> jax.Array:
    """Paged decode attention over pool pages + device tail: q (B,Hq,D),
    pages (P,B,page,Hkv,D), table (n,) → (B,Hq,D). Retraces only when the
    table *length* changes (one flush per page_size tokens) — the slot
    values ride in as data via scalar prefetch."""
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, page_table, k_tail, v_tail, tail_len,
        scale=scale, logit_cap=logit_cap, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jax.Array, a: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
             chunk: int) -> Tuple[jax.Array, jax.Array]:
    return ssd_scan_pallas(x, a, b_mat, c_mat, chunk, interpret=INTERPRET)
