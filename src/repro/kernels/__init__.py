"""Pallas TPU kernels for the perf-critical compute layers.

- flash_attention — prefill attention (GQA, sliding window, logit softcap)
- paged_attention — ring-cache decode attention (the HyperOffload serving
  hot path: consumes pool-prefetched KV blocks tile-by-tile)
- ssd_scan       — Mamba2 SSD chunked scan with VMEM state carry

Each has a jit wrapper in ``ops`` and a pure-jnp oracle in ``ref``;
``tests/test_kernels.py`` sweeps shapes/dtypes/flags in interpret mode.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
