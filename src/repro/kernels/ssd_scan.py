"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Chunked scan: grid (batch·heads, n_chunks) with the chunk dimension
innermost, carrying the (P×N) inter-chunk state in VMEM scratch across grid
steps — the TPU's sequential minor-to-major grid order makes the scratch a
legal scan carry. Per chunk:

  intra:  Y  = ((C·Bᵀ) ∘ exp(segsum(a)) ∘ tril) · X          (MXU matmuls)
  inter:  Y += exp(cumsum(a)) ∘ (C · stateᵀ)
  carry:  state ← state·exp(Σa) + Xᵀ·(B ∘ exp(Σa − cumsum(a)))

Inputs follow the SSD convention: X pre-scaled by dt, a = dt·A (negative).
The chunk length is the VMEM tile knob: work set ≈ L·(P+2N) + L² + P·N
floats.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)      # (L, P)
    a = a_ref[0].astype(jnp.float32)      # (L,)
    bm = b_ref[0].astype(jnp.float32)     # (L, N)
    cm = c_ref[0].astype(jnp.float32)     # (L, N)

    a_cs = jnp.cumsum(a)                  # (L,)
    # intra-chunk (diagonal block)
    seg = a_cs[:, None] - a_cs[None, :]   # (L, L)
    tril = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    l_mat = jnp.where(tril, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ()))) * l_mat
    y = jax.lax.dot(scores, x)            # (L, P)

    # inter-chunk contribution from the carried state (P, N)
    state = state_scr[...]
    y += jnp.exp(a_cs)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())))          # (L, P)

    # state update
    a_last = a_cs[-1]
    decay = jnp.exp(a_last - a_cs)[:, None]           # (L, 1)
    state_scr[...] = state * jnp.exp(a_last) + jax.lax.dot_general(
        x, bm * decay, (((0,), (0,)), ((), ())))      # (P, N)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        state_out_ref[0] = state_scr[...].astype(state_out_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,      # (B, S, H, P) pre-scaled by dt
    a: jax.Array,      # (B, S, H) = dt * A
    b_mat: jax.Array,  # (B, S, H, N)
    c_mat: jax.Array,  # (B, S, H, N)
    chunk: int,
    *,
    interpret: bool = True,
):
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # (B, S, H, ·) -> (B·H, S, ·)
    xt = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    at = a.transpose(0, 2, 1).reshape(bsz * h, s)
    bt = b_mat.transpose(0, 2, 1, 3).reshape(bsz * h, s, n)
    ct = c_mat.transpose(0, 2, 1, 3).reshape(bsz * h, s, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, at, bt, ct)

    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    state = state.reshape(bsz, h, p, n)
    return y, state
