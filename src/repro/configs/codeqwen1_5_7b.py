"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 architecture.

32 layers, d_model 4096, 32 heads (GQA kv=32 ⇒ MHA), d_ff 13440,
vocab 92416. RoPE (theta 1e6 for long-context code), SwiGLU.
"""

from repro.configs.base import LayerSpec, ModelConfig, Segment

DENSE = LayerSpec(mixer="attn", ffn="swiglu")

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    citation="hf:Qwen/CodeQwen1.5-7B",
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    segments=(Segment(pattern=(DENSE,), repeats=32),),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    long_context="swa-variant",
)
