"""Architecture registry: the 10 assigned architectures + workload shapes."""

from repro.configs.base import (
    DECODE_32K,
    INPUT_SHAPES,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    EncoderConfig,
    InputShape,
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    Segment,
)

from repro.configs.gemma2_9b import CONFIG as GEMMA2_9B
from repro.configs.mamba2_370m import CONFIG as MAMBA2_370M
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B
from repro.configs.phi3_mini_3_8b import CONFIG as PHI3_MINI
from repro.configs.zamba2_7b import CONFIG as ZAMBA2_7B
from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM
from repro.configs.codeqwen1_5_7b import CONFIG as CODEQWEN15_7B
from repro.configs.minicpm3_4b import CONFIG as MINICPM3_4B
from repro.configs.qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B

REGISTRY = {
    c.name: c
    for c in (
        GEMMA2_9B,
        MAMBA2_370M,
        GRANITE_MOE_3B,
        PHI3_MINI,
        ZAMBA2_7B,
        WHISPER_MEDIUM,
        CODEQWEN15_7B,
        MINICPM3_4B,
        QWEN2_VL_72B,
        MIXTRAL_8X22B,
    )
}

ARCH_IDS = tuple(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = [
    "REGISTRY",
    "ARCH_IDS",
    "get_config",
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
    "LayerSpec",
    "Segment",
    "MLAConfig",
    "MoEConfig",
    "SSMConfig",
    "EncoderConfig",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
]
