"""gemma2-9b [arXiv:2408.00118].

42 layers, d_model 3584, 16 heads (GQA kv=8), head_dim 256, d_ff 14336,
vocab 256000. Alternating local (sliding-window 4096) / global attention,
attention-logit softcap 50, final-logit softcap 30, gemma-style
pre+post sublayer RMSNorms, tied embeddings.
"""

from repro.configs.base import LayerSpec, ModelConfig, Segment

LOCAL = LayerSpec(mixer="attn", ffn="swiglu", window=4096, post_norms=True)
GLOBAL = LayerSpec(mixer="attn", ffn="swiglu", window=None, post_norms=True)

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    citation="arXiv:2408.00118",
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    segments=(Segment(pattern=(LOCAL, GLOBAL), repeats=21),),  # 42 layers
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_scale=256 ** -0.5,
    long_context="native",  # alternating SWA bounds local KV; global layers keep full cache
)
