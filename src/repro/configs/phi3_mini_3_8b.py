"""phi3-mini-3.8b [arXiv:2404.14219].

32 layers, d_model 3072, 32 heads (GQA kv=32 ⇒ MHA), d_ff 8192,
vocab 32064. RoPE + SwiGLU.
"""

from repro.configs.base import LayerSpec, ModelConfig, Segment

DENSE = LayerSpec(mixer="attn", ffn="swiglu")

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    citation="arXiv:2404.14219",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    segments=(Segment(pattern=(DENSE,), repeats=32),),
    rope_theta=10000.0,
    tie_embeddings=False,
    long_context="swa-variant",
)
