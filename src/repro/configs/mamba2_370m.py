"""mamba2-370m [arXiv:2405.21060] — SSD (state-space duality).

48 layers, d_model 1024, attention-free, vocab 50280, ssm_state 128.
Mamba2 blocks have no separate FFN (the block itself is the mixer+MLP).
"""

from repro.configs.base import LayerSpec, ModelConfig, SSMConfig, Segment

MAMBA = LayerSpec(mixer="mamba2", ffn="none")

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    citation="arXiv:2405.21060",
    d_model=1024,
    n_heads=1,          # unused (attention-free); SSD heads come from SSMConfig
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    segments=(Segment(pattern=(MAMBA,), repeats=48),),
    rope_mode="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, n_groups=1,
                  chunk_size=256),
    long_context="native",  # recurrent state: O(1) memory per decode step
)
