"""zamba2-7b [arXiv:2411.15242] — Mamba2 backbone + periodic shared attention.

81 layers, d_model 3584, attention blocks with 32 heads (kv=32),
d_ff 14336, vocab 32000, ssm_state 64. We model the hybrid as a repeated
pattern of 5 Mamba2 blocks followed by 1 attention+SwiGLU block
(13 periods = 78 layers) plus a 3-layer Mamba2 epilogue (81 total).
"""

from repro.configs.base import LayerSpec, ModelConfig, SSMConfig, Segment

MAMBA = LayerSpec(mixer="mamba2", ffn="none")
ATTN = LayerSpec(mixer="attn", ffn="swiglu")

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    citation="arXiv:2411.15242",
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    segments=(
        Segment(pattern=(MAMBA, MAMBA, MAMBA, MAMBA, MAMBA, ATTN), repeats=13),
        Segment(pattern=(MAMBA,), repeats=3),
    ),
    rope_theta=10000.0,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, n_groups=1,
                  chunk_size=256),
    long_context="native",  # SSM state O(1); only 13 attention layers hold KV
)
