"""whisper-medium [arXiv:2212.04356] — encoder-decoder, audio.

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA), d_ff 4096,
vocab 51865. LayerNorm, GELU MLP, learned positions, decoder cross-attention.
The mel-spectrogram + conv frontend is a STUB per the assignment:
``input_specs()`` supplies precomputed 1500-frame encoder embeddings.
"""

from repro.configs.base import EncoderConfig, LayerSpec, ModelConfig, Segment

DEC = LayerSpec(mixer="attn", ffn="gelu", cross_attn=True)

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    citation="arXiv:2212.04356",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    segments=(Segment(pattern=(DEC,), repeats=24),),
    norm="layernorm",
    norm_eps=1e-5,
    rope_mode="learned",
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    frontend="audio",
    long_context="swa-variant",  # decoder is full attention; see DESIGN.md §5
)
