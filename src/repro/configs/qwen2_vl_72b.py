"""qwen2-vl-72b [arXiv:2409.12191] — M-RoPE, dynamic resolution VLM.

80 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064.
M-RoPE splits head_dim 128 rotary channels into (temporal 16, height 24,
width 24) sections driven by 3-D position ids. The ViT vision encoder +
projector is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings and a scatter mask.
"""

from repro.configs.base import LayerSpec, ModelConfig, Segment

DENSE = LayerSpec(mixer="attn", ffn="swiglu")

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    citation="arXiv:2409.12191",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    segments=(Segment(pattern=(DENSE,), repeats=80),),
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    frontend="vision",
    long_context="swa-variant",
)
