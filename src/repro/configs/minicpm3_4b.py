"""minicpm3-4b [hf:openbmb/MiniCPM3-4B] — Multi-head Latent Attention (MLA).

62 layers, d_model 2560, 40 heads, d_ff 6400, vocab 73448. MLA compresses
the KV cache to a low-rank latent (kv_lora_rank 256 + 32 rope dims per
token per layer), so long_500k runs natively: the compressed cache at 500k
tokens is ~18 GB global — smaller than a full-attention 4k cache of a 7B
model. Decode cost per step is O(S) in the latent space.
"""

from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, Segment

MLA_LAYER = LayerSpec(mixer="mla", ffn="swiglu")

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    citation="hf:openbmb/MiniCPM3-4B",
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    segments=(Segment(pattern=(MLA_LAYER,), repeats=62),),
    rope_theta=10000.0,
    tie_embeddings=True,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    long_context="native",  # MLA latent cache is sub-linear in bytes vs full KV
)
