"""Configuration system for model architectures and workload shapes.

Every assigned architecture is expressed as a ``ModelConfig`` built from
repeated layer *segments* — ``(pattern, repeats)`` pairs — so heterogeneous
stacks (gemma2 local/global alternation, zamba2 mamba+attention hybrid) lower
through ``jax.lax.scan`` over each repeated pattern with stacked parameters.
This keeps the HLO compact enough to compile the full 40–80 layer production
configs on the CPU dry-run host.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0          # per-expert hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration [arXiv:2405.21060]."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    ``input_specs`` supplies precomputed frame embeddings."""

    n_layers: int = 24
    n_frames: int = 1500


# ---------------------------------------------------------------------------
# Layer specs & segments
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer = mixer + ffn, pre-norm residual structure.

    mixer: "attn" | "mla" | "mamba2"
    ffn:   "swiglu" | "gelu" | "moe" | "none"
    window: sliding-window size for this layer's attention (None = full)
    cross_attn: whisper decoder layers attend to encoder output
    """

    mixer: str = "attn"
    ffn: str = "swiglu"
    window: Optional[int] = None
    cross_attn: bool = False
    post_norms: bool = False      # gemma2-style post-sublayer RMSNorm


@dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | moe | hybrid | audio | vlm
    citation: str

    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    d_ff: int = 2048
    vocab_size: int = 32000

    segments: Tuple[Segment, ...] = ()

    # normalization / activation
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-6

    # position encoding
    rope_mode: str = "rope"       # rope | mrope | learned | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # logits
    tie_embeddings: bool = True
    scale_embeddings: bool = False   # multiply token embeddings by sqrt(d_model)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None   # override 1/sqrt(head_dim)

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: str = "none"        # none | audio | vision

    # long-context policy: "native" (sub-quadratic as-is) or window size used
    # by the documented sliding-window variant for long_500k (see DESIGN.md §5)
    long_context: str = "native"  # native | swa-variant
    swa_variant_window: int = 4096

    # pad the embedding/logits vocab dimension to a multiple so it shards
    # over the model axis (odd vocabs like 50280/49155 otherwise replicate
    # multi-GB f32 logits on every device). 1 = exact vocab (baseline);
    # the §Perf hillclimb and production configs use 256.
    vocab_pad_multiple: int = 1

    # ------------------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return -(-self.vocab_size // m) * m

    @property
    def q_dim(self) -> int:
        if self.mla is not None:
            return self.n_heads * (self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim)
        return self.n_heads * self.head_dim

    def layer_specs(self):
        """Flatten segments to the full per-layer spec list (for analysis)."""
        out = []
        for seg in self.segments:
            for _ in range(seg.repeats):
                out.extend(seg.pattern)
        return out

    def param_count(self) -> int:
        """Analytic parameter count (used by the cost model & roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        total += d  # final norm
        for spec in self.layer_specs():
            total += self._mixer_params(spec) + self._ffn_params(spec) + self._norm_params(spec)
        if self.encoder is not None:
            enc_spec = LayerSpec(mixer="attn", ffn="gelu")
            per = self._mixer_params(enc_spec) + self._ffn_params(enc_spec) + self._norm_params(enc_spec)
            total += self.encoder.n_layers * per + self.d_model
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        per_expert = 3 * d * m.d_ff_expert
        total -= n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total

    def _mixer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.mixer == "mamba2":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_ssm_heads(d)
            conv_dim = di + 2 * s.n_groups * s.d_state
            in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            return in_proj + conv_dim * s.d_conv + conv_dim + 3 * nh + di + di * d
        if spec.mixer == "mla":
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
            return n
        # GQA attention
        n = d * self.n_heads * self.head_dim          # wq
        n += 2 * d * self.n_kv_heads * self.head_dim  # wk, wv
        n += self.n_heads * self.head_dim * d         # wo
        if spec.cross_attn:
            n *= 2
        return n

    def _ffn_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.ffn == "none":
            return 0
        if spec.ffn == "moe":
            m = self.moe
            return d * m.n_experts + m.n_experts * 3 * d * m.d_ff_expert
        if spec.ffn == "gelu":
            return 2 * d * self.d_ff
        return 3 * d * self.d_ff  # swiglu

    def _norm_params(self, spec: LayerSpec) -> int:
        n = 2 * self.d_model
        if spec.post_norms:
            n += 2 * self.d_model
        if spec.cross_attn:
            n += self.d_model
        return n

    # ------------------------------------------------------------------
    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache footprint per sequence token across all layers."""
        total = 0
        for spec in self.layer_specs():
            if spec.mixer == "attn":
                total += 2 * self.n_kv_heads * self.head_dim * dtype_bytes
                if spec.cross_attn:
                    pass  # cross KV is per-request, not per-token
            elif spec.mixer == "mla":
                m = self.mla
                total += (m.kv_lora_rank + m.qk_rope_head_dim) * dtype_bytes
        return total

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 layers per distinct pattern, d_model≤256,
        ≤4 experts, small vocab. Same family/block structure."""
        small_segments = tuple(
            Segment(pattern=seg.pattern, repeats=min(1, seg.repeats))
            for seg in self.segments[:2]
        )
        d_model = min(self.d_model, 256)
        head_dim = min(self.head_dim, 32)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads)
        kw = dict(
            name=self.name + "-smoke",
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            segments=small_segments,
        )
        if self.moe is not None:
            n_e = min(4, self.moe.n_experts)
            t_k = min(2, self.moe.top_k)
            # lossless capacity in smoke configs so decode == full forward
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=n_e, top_k=t_k,
                d_ff_expert=min(128, self.moe.d_ff_expert),
                capacity_factor=float(n_e) / t_k)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(32, self.ssm.d_state), headdim=32,
                chunk_size=64)
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=16, qk_rope_head_dim=16, v_head_dim=16)
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(self.encoder, n_layers=1, n_frames=16)
        if self.mrope_sections != (16, 24, 24):
            pass
        if self.rope_mode == "mrope":
            half = head_dim // 2
            t = half // 4
            kw["mrope_sections"] = (t, (half - t) // 2, half - t - (half - t) // 2)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned workload shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
