"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32 layers, d_model 1536, 24 heads (GQA kv=8), per-expert d_ff 512,
vocab 49155, MoE with 40 experts, top-8 routing.
"""

from repro.configs.base import LayerSpec, MoEConfig, ModelConfig, Segment

MOE_LAYER = LayerSpec(mixer="attn", ffn="moe")

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    segments=(Segment(pattern=(MOE_LAYER,), repeats=32),),
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                  capacity_factor=1.25),
    long_context="swa-variant",  # full attention: long_500k via documented SWA variant
)
