"""mixtral-8x22b [arXiv:2401.04088] — 8-expert top-2 MoE with sliding-window attention.

56 layers, d_model 6144, 48 heads (GQA kv=8), per-expert d_ff 16384,
vocab 32768. Every layer is MoE; SWA window 4096 bounds the KV cache so
long_500k runs natively.
"""

from repro.configs.base import LayerSpec, MoEConfig, ModelConfig, Segment

MOE_SWA = LayerSpec(mixer="attn", ffn="moe", window=4096)

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    citation="arXiv:2401.04088",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    segments=(Segment(pattern=(MOE_SWA,), repeats=56),),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25),
    long_context="native",  # SWA bounds KV to the window
)
