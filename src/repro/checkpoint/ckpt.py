"""Numpy-based pytree checkpointing (no orbax dependency).

Flattens any params/optimizer pytree with jax.tree_util key paths into an
``.npz`` plus a tiny JSON manifest; restore rebuilds the exact tree and
re-places leaves on the current devices. Atomic via write-to-temp + rename.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16, fp8) → f32 on disk
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    tmp = tempfile.NamedTemporaryFile(
        dir=os.path.dirname(path) or ".", suffix=".tmp", delete=False)
    try:
        np.savez(tmp, **flat)
        tmp.close()
        os.replace(tmp.name, path)
    finally:
        if os.path.exists(tmp.name):
            os.unlink(tmp.name)
    with open(path + ".json", "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "keys": sorted(flat)}, f)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path)
    with open(path + ".json") as f:
        meta = json.load(f)
    flat_like = jax.tree_util.tree_flatten_with_path(like)[0]
    leaves = []
    for pathk, leaf in flat_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pathk)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["step"]
