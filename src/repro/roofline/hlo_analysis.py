"""Loop-aware static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which makes a
scan-over-layers model look ~n_layers× cheaper than it is. XLA records the
real trip count in ``backend_config={"known_trip_count":{"n":...}}``, so we
parse the module into computations, walk the call graph from ENTRY
(while bodies inherit multiplier × trip_count; fusions/calls inherit ×1),
and accumulate per-instruction:

- FLOPs:            dot ops — 2 · |result| · Π(lhs contracting dims)
- HBM bytes:        per top-level instruction, operands + results (the
                    fusion is XLA's memory-traffic unit)
- collective bytes: result sizes of all-reduce / all-gather /
                    reduce-scatter / all-to-all / collective-permute

This is the §Roofline source for HLO_FLOPs / HLO_bytes / collective_bytes.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_DOT_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call", "partition-id",
    "replica-id", "copy-start", "copy-done", "reshape",
}

# bare elementwise ops: the CPU backend leaves many unfused that the TPU
# backend would fuse into neighbours — modeling them as free approximates
# TPU fusion granularity (documented assumption; see module docstring)
_FUSABLE_OPS = {
    "convert", "multiply", "add", "subtract", "divide", "select", "compare",
    "exponential", "tanh", "maximum", "minimum", "negate", "abs", "and",
    "or", "not", "xor", "log", "power", "rsqrt", "sqrt", "floor", "ceil",
    "clamp", "sign", "is-finite", "reduce-precision", "round-nearest-afz",
    "exponential-minus-one", "log-plus-one", "atan2", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}

# ops whose first operand is a large buffer they only touch a slice of
_SLICING_OPS = {"dynamic-slice", "gather", "slice"}
_UPDATING_OPS = {"dynamic-update-slice", "scatter"}


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class Instruction:
    name: str
    shape: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instruction] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> shape str


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            # parameter lines: "  %p = TYPE parameter(0)" match the instr
            # regex; tuple-typed ones may not — capture shapes generically
            pm = re.match(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*?)\s+parameter\(", line)
            if pm:
                cur.symbols[pm.group(1)] = pm.group(2)
                cur.instrs.append(Instruction(pm.group(1), pm.group(2), "parameter", ""))
            continue
        name, shape, op, rest = m.groups()
        cur.symbols[name] = shape
        cur.instrs.append(Instruction(name, shape, op, rest))
    return comps


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = comps.get("__entry__")
    mult: Dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry.name] = 1.0
    # BFS over the call graph
    stack = [entry.name]
    seen_edges = set()
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for ins in comp.instrs:
            if ins.op == "while":
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _CALLS_RE.search(ins.rest)
                cm = _COND_RE.search(ins.rest)
                if bm:
                    child = bm.group(1)
                    key = (cname, child, ins.name)
                    if key not in seen_edges:
                        seen_edges.add(key)
                        mult[child] = mult.get(child, 0.0) + m * trip
                        stack.append(child)
                if cm:
                    child = cm.group(1)
                    key = (cname, child, ins.name + "#cond")
                    if key not in seen_edges:
                        seen_edges.add(key)
                        mult[child] = mult.get(child, 0.0) + m * trip
                        stack.append(child)
            else:
                for cm_ in _CALLS_RE.finditer(ins.rest):
                    child = cm_.group(1)
                    key = (cname, child, ins.name)
                    if key not in seen_edges:
                        seen_edges.add(key)
                        mult[child] = mult.get(child, 0.0) + m
                        stack.append(child)
    return mult


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    dims = _shape_dims(ins.shape)
    if not dims:
        return 0.0
    _, rdims = dims[0]
    n_out = 1
    for d in rdims:
        n_out *= d
    lhs_m = _OPERAND_RE.search(ins.rest)
    contract = _DOT_LHS_CONTRACT.search(ins.rest)
    k = 1
    if lhs_m and contract:
        lhs_shape = comp.symbols.get(lhs_m.group(1))
        if lhs_shape:
            ldims = _shape_dims(lhs_shape)
            if ldims:
                _, ld = ldims[0]
                for ci in [int(x) for x in contract.group(1).split(",") if x]:
                    if ci < len(ld):
                        k *= ld[ci]
    return 2.0 * n_out * k


def _fusion_bytes(ins: Instruction, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    """Traffic of a fusion = result + per-parameter bytes actually read.
    A parameter whose only in-fusion uses are slicing ops contributes the
    slice sizes, not the full buffer (fused dynamic-slice of stacked layer
    params inside a scan body reads one layer, not all of them)."""
    total = float(_shape_bytes(ins.shape))
    cm = _CALLS_RE.search(ins.rest)
    fused = comps.get(cm.group(1)) if cm else None
    operand_names = [om.group(1) for om in
                     _OPERAND_RE.finditer(ins.rest.split(" calls=")[0])]
    operand_shapes = [comp.symbols.get(n) for n in operand_names]
    if fused is None:
        return total + sum(_shape_bytes(s) for s in operand_shapes if s)
    # order of parameter(i) instructions maps to operand order
    params = [i for i in fused.instrs if i.op == "parameter"]
    param_uses: Dict[str, List[Instruction]] = {p.name: [] for p in params}
    for fi in fused.instrs:
        if fi.op == "parameter":
            continue
        for om in _OPERAND_RE.finditer(fi.rest):
            if om.group(1) in param_uses:
                param_uses[om.group(1)].append(fi)
    for idx, p in enumerate(params):
        oshape = operand_shapes[idx] if idx < len(operand_shapes) else None
        full = _shape_bytes(oshape) if oshape else _shape_bytes(p.shape)
        uses = param_uses.get(p.name, [])
        if uses and all(u.op in _SLICING_OPS for u in uses):
            total += sum(_shape_bytes(u.shape) for u in uses)
        elif uses and all(u.op in _UPDATING_OPS for u in uses):
            upd = 0
            for u in uses:
                ops_ = [fused.symbols.get(om.group(1))
                        for om in _OPERAND_RE.finditer(u.rest)]
                upd += _shape_bytes(ops_[1]) if len(ops_) > 1 and ops_[1] else _shape_bytes(u.shape)
            total += min(full, upd)
        else:
            total += full
    return total


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    n_collectives: int = 0


def analyze(text: str) -> HloStats:
    comps = parse_module(text)
    comps_by_name = {k: v for k, v in comps.items() if k != "__entry__"}
    mult = _multipliers(comps)
    stats = HloStats(coll_breakdown={k: 0.0 for k in COLLECTIVE_OPS})

    # computations that are fusion bodies: their traffic is accounted at the
    # fusion instruction — only dot FLOPs are collected inside them
    fusion_bodies = set()
    for comp in comps_by_name.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    fusion_bodies.add(cm.group(1))

    for cname, comp in comps_by_name.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion_body = cname in fusion_bodies
        for ins in comp.instrs:
            base_op = ins.op
            if base_op.endswith("-start"):
                base_op = base_op[:-6]
            if base_op == "dot":
                stats.flops += m * _dot_flops(ins, comp)
            if base_op in COLLECTIVE_OPS:
                b = _shape_bytes(ins.shape)
                stats.collective_bytes += m * b
                stats.coll_breakdown[base_op] += m * b
                stats.n_collectives += int(m)
            if (in_fusion_body or ins.op in _SKIP_BYTES_OPS
                    or ins.op in _FUSABLE_OPS or ins.op.endswith("-done")
                    or base_op in COLLECTIVE_OPS):
                continue
            if ins.op in _SLICING_OPS:
                # reads + writes only the extracted slice
                stats.hbm_bytes += m * 2 * _shape_bytes(ins.shape)
                continue
            if ins.op in _UPDATING_OPS:
                # touches only the update operand's extent (operand #1)
                ops_ = [comp.symbols.get(om.group(1))
                        for om in _OPERAND_RE.finditer(ins.rest)]
                upd = ops_[1] if len(ops_) > 1 and ops_[1] else ins.shape
                stats.hbm_bytes += m * 2 * _shape_bytes(upd)
                continue
            if ins.op == "broadcast":
                stats.hbm_bytes += m * _shape_bytes(ins.shape)
                continue
            if ins.op == "fusion":
                stats.hbm_bytes += m * _fusion_bytes(ins, comp, comps_by_name)
                continue
            # default: operands + result (the fusion is the traffic unit)
            nbytes = _shape_bytes(ins.shape)
            for om in _OPERAND_RE.finditer(ins.rest.split(" calls=")[0]):
                oshape = comp.symbols.get(om.group(1))
                if oshape:
                    nbytes += _shape_bytes(oshape)
            stats.hbm_bytes += m * nbytes
    return stats
