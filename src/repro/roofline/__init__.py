from repro.roofline.analysis import (
    RooflineTerms,
    collective_bytes,
    model_flops,
    roofline_from_compiled,
)

__all__ = ["RooflineTerms", "collective_bytes", "model_flops",
           "roofline_from_compiled"]
