"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_bytes / link_bw         (per chip)

``compiled.cost_analysis()`` supplies FLOPs and bytes; collective bytes are
parsed out of the optimized (post-SPMD-partitioning) HLO text by summing
the result sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op. Hardware constants: TPU v5e —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.costmodel import TPU_V5E, HardwareSpec
from repro.configs.base import ModelConfig

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

# matches e.g. "bf16[16,512]{1,0}" — dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# a collective instruction line: "%name = <shape(s)> <op>("
_INSTR_RE = re.compile(
    r"=\s+(.+?)\s+(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-operand bytes of every collective in optimized HLO,
    keyed by op kind. ``-done`` ops are skipped (their ``-start`` carries
    the payload)."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_str)
    return out


def model_flops(cfg: ModelConfig, tokens: int, train: bool = True) -> float:
    """MODEL_FLOPS = 6·N·D (train: fwd+bwd) or 2·N·D (inference fwd only),
    with N = active params (MoE: top-k only)."""
    factor = 6.0 if train else 2.0
    return factor * cfg.active_param_count() * tokens


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: int
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0
    per_device_peak_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return 0.0 if self.flops == 0 else self.model_flops / self.flops

    def row(self) -> Dict[str, object]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_bytes_per_device": self.per_device_peak_bytes,
        }


def roofline_from_compiled(compiled, cfg: Optional[ModelConfig] = None,
                           tokens: int = 0, n_devices: int = 1,
                           hw: HardwareSpec = TPU_V5E,
                           train: bool = True) -> RooflineTerms:
    """Derive the three terms from a compiled executable.

    Uses the loop-aware HLO analyzer (hlo_analysis.analyze) rather than
    ``cost_analysis()`` — XLA's cost analysis counts each ``while`` body
    once, which under-counts a scan-over-layers model by ~n_layers×. All
    figures are for the per-device module (post-SPMD partitioning)."""
    from repro.roofline import hlo_analysis
    stats = hlo_analysis.analyze(compiled.as_text())
    flops = stats.flops
    nbytes = stats.hbm_bytes
    coll = {k: int(v) for k, v in stats.coll_breakdown.items()}
    coll_total = int(stats.collective_bytes)
    mem_stats = compiled.memory_analysis()
    peak = (mem_stats.argument_size_in_bytes
            + mem_stats.output_size_in_bytes
            + mem_stats.temp_size_in_bytes
            - mem_stats.alias_size_in_bytes)
    mf = model_flops(cfg, tokens, train) / max(n_devices, 1) if cfg is not None else 0.0
    return RooflineTerms(
        compute_s=flops / hw.flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=coll_total / hw.link_bw,
        flops=flops,
        hbm_bytes=nbytes,
        coll_bytes=coll_total,
        coll_breakdown=coll,
        model_flops=mf,
        per_device_peak_bytes=peak,
    )
