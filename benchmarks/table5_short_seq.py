"""Table 5 reproduction: short-sequence inference latency breakdown.

Paper: prefill parity (<1 % — 62.19 vs 62.49 s), decode slowdown under
coarse sparse blocks (0.117 → 0.146 s/token, +25.5 %), end-to-end ≈0.15 %.

Decode overhead model: the hierarchical path's per-step cost adds CPU-side
sparse-block selection + partial KV-cache update processing — bytes of the
selected blocks moving through host-side copies at CPU_COPY_BW. The paper
notes (§7.4) this grows with sparse-block granularity; table6 sweeps it.

NOTE (recorded in EXPERIMENTS.md): the paper's own Table 5 is internally
inconsistent — prefill 62.5 s + hundreds of 0.146 s decode steps cannot
total 177.1 s while the baseline with 0.117 s steps totals 177.4 s. We
reproduce each row's metric and report a *consistent* derived end-to-end.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import insertion, memsim, timeline, tracer
from repro.core.costmodel import ASCEND_LIKE

from benchmarks.paper_models import DEEPSEEK_V3_FULL

SHARDS = 8
BATCH = 26
SEQ_SHORT = 16_384
W4 = 0.53
KV_READ_FRACTION = 0.06
CPU_COPY_BW = 30e9           # host-side block processing throughput (calibrated
                             # to the paper's +25.5 % decode point; the
                             # granularity sweep in table6 is the prediction)
DECODE_TOKENS = 128          # short-generation regime (see EXPERIMENTS.md
                             # on the paper's internally inconsistent e2e)


def decode_token_time(remote_kv: bool, seq: int = SEQ_SHORT,
                      block_efficiency: float = 1.0) -> float:
    """Per-token decode latency. ``block_efficiency`` < 1 models coarser
    sparse blocks (more over-fetch + CPU processing per selected byte)."""
    opts = tracer.TraceOptions(shards=SHARDS, remote_kv=remote_kv,
                               remote_opt_states=False, weight_dtype_bytes=W4,
                               kv_read_fraction=KV_READ_FRACTION)
    g = tracer.trace_decode_step(DEEPSEEK_V3_FULL, BATCH, seq, opts)
    if remote_kv:
        g = insertion.insert_cache_ops(
            g, ASCEND_LIKE,
            insertion.InsertionOptions(offload_activations=False,
                                       force_prefixes=("kv_",)))
        tl = timeline.simulate(g, ASCEND_LIKE)
        kv_read = sum(info.nbytes for t, info in g.tensors.items()
                      if t.startswith("kv_"))
        cpu = kv_read / (CPU_COPY_BW * block_efficiency)
        return tl.total + cpu
    return timeline.simulate(g.residentize(), ASCEND_LIKE).total


def prefill_time(remote_kv: bool) -> float:
    opts = tracer.TraceOptions(shards=SHARDS, remote_kv=remote_kv,
                               remote_opt_states=False, weight_dtype_bytes=W4,
                               kv_read_fraction=KV_READ_FRACTION)
    g = tracer.trace_prefill(DEEPSEEK_V3_FULL, BATCH, SEQ_SHORT, opts)
    if remote_kv:
        g = insertion.insert_cache_ops(
            g, ASCEND_LIKE,
            insertion.InsertionOptions(offload_activations=False,
                                       force_prefixes=("kv_",)))
        return timeline.simulate(g, ASCEND_LIKE).total
    return timeline.simulate(g.residentize(), ASCEND_LIKE).total


def run(block_efficiency: float = 1.0) -> List[Dict]:
    pre_b, pre_o = prefill_time(False), prefill_time(True)
    dec_b = decode_token_time(False)
    dec_o = decode_token_time(True, block_efficiency=block_efficiency)
    e2e_b = pre_b + DECODE_TOKENS * dec_b
    e2e_o = pre_o + DECODE_TOKENS * dec_o
    return [{
        "metric": "prefill_latency_s", "baseline": pre_b, "hierarchical": pre_o,
        "relative_change": (pre_o - pre_b) / pre_b, "paper_change": -0.0048,
    }, {
        "metric": "decode_latency_s", "baseline": dec_b, "hierarchical": dec_o,
        "relative_change": (dec_o - dec_b) / dec_b, "paper_change": 0.2547,
    }, {
        "metric": "end_to_end_latency_s", "baseline": e2e_b, "hierarchical": e2e_o,
        "relative_change": (e2e_o - e2e_b) / e2e_b, "paper_change": -0.0015,
    }]


def main():
    for r in run():
        print("table5,%s,%.4f,%.4f,%.4f,paper:%.4f" % (
            r["metric"], r["baseline"], r["hierarchical"],
            r["relative_change"], r["paper_change"]))


if __name__ == "__main__":
    main()
