"""Continuous batching vs. static batching on mixed-length Poisson traffic.

Two schedulers over the same arrival trace and the same model:

- **static**  — the seed's serving pattern: requests are grouped into
  arrival-order batches of ``max_batch``; a batch prefills together
  (prompts end-padded to the batch max) and decodes until its *longest*
  member finishes — short requests burn padded decode steps and late
  requests wait for the whole previous batch.
- **continuous** — ``sched.ContinuousScheduler``: sequences join and
  retire every decode step, so a retired slot is refilled immediately and
  nobody decodes padding.

Reported: wall-clock generated tokens/s, virtual-step throughput, and
p50/p99 request latency in scheduler steps (finish − arrival on the
deterministic virtual clock; 1 step = one batched decode). A second,
small ``kv_offload`` run reports the plan-driven prefetcher's stats —
fetches issued ahead of consumption (plan lead ≥ 1, overlapped waits)
instead of the old store-then-immediately-wait round trip.

A third section drives a mixed short/long-prompt trace through the
scheduler step by step, whole-prompt vs **chunked prefill**
(``--chunk-size``): per-step prefill tokens and wall latency show the
long-prompt stall bounded by the chunk budget, and the jit cache sizes
show chunked prefill compiling exactly ONE executable where whole-prompt
prefill compiles one per distinct prompt length.

A fourth section replays a **shared-prefix** trace (``poisson_trace``'s
prefix-family mode: ~2/3 of every prompt is one of two shared prefixes)
with the cross-request prefix cache off vs on: after warming one request
per family, every later request's shared pages come from the cache, so
prefill tokens drop by the shared fraction while the emitted tokens stay
identical. Reported: prefill tokens saved, hit rate, tokens/s both ways.

    PYTHONPATH=src python benchmarks/serve_continuous.py [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import HyperOffloadSession, OffloadConfig
from repro.api.config import PrefixCacheConfig, TelemetryConfig
from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.obs import OverlapAnalyzer
from repro.offload.kvcache import worst_case_page_bytes
from repro.pool import TierSpec, TierTopology
from repro.sched import Request, poisson_trace
from repro.serving.engine import jit_prefill_chunk
from repro.slo import SLOConfig, SLOSpec, attainment_summary


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


# ---------------------------------------------------------------------------
# static-batching baseline
# ---------------------------------------------------------------------------


def run_static(session, model, params, trace: List[Request],
               max_batch: int) -> Dict[str, float]:
    engine = session.serve_engine(model, params, offload_kv=False)
    clock = 0.0
    latencies: List[float] = []
    tokens = 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), max_batch):
        batch = trace[i:i + max_batch]
        start = max(clock, max(r.arrival for r in batch))
        s_max = max(r.prompt_len for r in batch)
        # a partial final batch is padded with copies of its last request
        # (uncounted) so the engine only ever sees full-batch shapes
        padded = np.zeros((max_batch, s_max), np.int32)
        for j in range(max_batch):
            r = batch[min(j, len(batch) - 1)]
            padded[j, :r.prompt_len] = r.tokens
        steps = max(r.max_new_tokens for r in batch)
        engine.generate({"tokens": jnp.asarray(padded)}, steps)
        clock = start + steps        # everyone waits for the longest member
        tokens += sum(r.max_new_tokens for r in batch)
        latencies += [clock - r.arrival for r in batch]
    wall = time.perf_counter() - t0
    engine.close()
    return {
        "tokens": tokens, "wall_s": wall, "virtual_steps": clock,
        "tokens_per_s": tokens / wall,
        "tokens_per_step": tokens / max(clock, 1e-9),
        "p50_latency_steps": _pct(latencies, 50),
        "p99_latency_steps": _pct(latencies, 99),
    }


# ---------------------------------------------------------------------------
# continuous scheduler
# ---------------------------------------------------------------------------


def run_continuous(session, model, params, trace: List[Request], *,
                   kv_offload: bool = False) -> Dict[str, float]:
    sched = session.scheduler(model, params, kv_offload=kv_offload)
    t0 = time.perf_counter()
    out = sched.run(trace)
    wall = time.perf_counter() - t0
    tokens = sum(len(v) for v in out.values())
    lats = [st.t_done - st.request.arrival for st in sched.finished.values()]
    res = {
        "tokens": tokens, "wall_s": wall, "virtual_steps": sched.now,
        "tokens_per_s": tokens / wall,
        "tokens_per_step": tokens / max(sched.now, 1e-9),
        "p50_latency_steps": _pct(lats, 50),
        "p99_latency_steps": _pct(lats, 99),
        "joins": sched.stats.joins, "retires": sched.stats.retires,
        "admission_blocked": sched.admission.blocked,
    }
    if kv_offload:
        snap = sched.pool_stats()
        res["prefetch"] = sched.prefetch_stats()
        res["transfer"] = snap["transfer"]
        res["pool_evictions"] = snap["evictions"]
        res["pages_parked"] = sched.stats.pages_parked
        res["cold_spills"] = sched.stats.cold_spills
        if session.config.telemetry.enable:
            # the overlap proof: decompose the trace into hidden vs
            # exposed transfer time, cross-checked against the engine's
            # own wait counters — a disagreement is a bug, not noise
            analyzer = OverlapAnalyzer.from_tracer(session.tracer)
            errs = analyzer.validate(snap["transfer"])
            assert not errs, f"overlap/TransferStats disagree: {errs}"
            res["overlap"] = analyzer.report()
    sched.close()
    return res


# ---------------------------------------------------------------------------
# chunked prefill vs whole-prompt on long-prompt traffic
# ---------------------------------------------------------------------------


def run_continuous_stepwise(session, model, params, trace: List[Request], *,
                            chunk_size=None,
                            prefill_tokens=None) -> Dict[str, float]:
    """Drive the scheduler step by step, recording per-step wall latency
    and per-step prefill tokens — the stall metric: whole-prompt prefill
    spends an entire prompt in one step, chunked prefill never exceeds its
    token budget."""
    overrides = {}
    if chunk_size is not None:
        overrides = dict(chunk_size=chunk_size, prefill_tokens=prefill_tokens)
    sched = session.scheduler(model, params, **overrides)
    for r in trace:
        sched.submit(r)
    # run()'s no-progress guard: a scheduler stall must fail CI with a
    # diagnostic, not hang it
    max_steps = sched.default_max_steps()
    step_wall_ms: List[float] = []
    step_prefill: List[int] = []
    t0 = time.perf_counter()
    while len(sched.queue) or sched.active:
        if not sched.active and sched.queue.head_ready(sched.now) is None:
            sched.now = max(sched.now, sched.queue.next_arrival())
        before = sched.stats.prefill_tokens
        s0 = time.perf_counter()
        sched.step()
        step_wall_ms.append((time.perf_counter() - s0) * 1e3)
        step_prefill.append(sched.stats.prefill_tokens - before)
        if len(step_wall_ms) > max_steps:
            raise RuntimeError(
                f"scheduler made no progress ({len(step_wall_ms)} steps, "
                f"{len(sched.queue)} queued)")
    wall = time.perf_counter() - t0
    tokens = sum(len(st.out) for st in sched.finished.values())
    lats = [st.t_done - st.request.arrival for st in sched.finished.values()]
    res = {
        "tokens": tokens, "wall_s": wall,
        "virtual_steps": sched.now,
        "tokens_per_s": tokens / wall,
        "p50_latency_steps": _pct(lats, 50),
        "p99_latency_steps": _pct(lats, 99),
        "max_step_prefill_tokens": max(step_prefill),
        "p99_step_prefill_tokens": _pct([float(x) for x in step_prefill], 99),
        "p99_step_wall_ms": _pct(step_wall_ms, 99),
        "prefill_chunks": sched.stats.prefill_chunks,
    }
    sched.close()
    return res


def _jit_cache_size(fn):
    """Compiled-executable count of a jitted entry point, via jax's
    private ``_cache_size`` — None when a jax version doesn't expose it
    (callers must treat None as 'unknown', not assert on it)."""
    return fn._cache_size() if hasattr(fn, "_cache_size") else None


def run_long_prompt_comparison(session, model, params, trace: List[Request],
                               chunk_size: int,
                               prefill_tokens) -> Dict[str, Dict[str, float]]:
    budget = prefill_tokens or chunk_size
    # warm every prefill shape the trace needs OUTSIDE the timed runs, so
    # the step-latency comparison measures scheduling stalls rather than
    # XLA compiles — and count executables over this warm phase: one per
    # distinct prompt length for whole-prompt prefill, exactly ONE for the
    # chunk path regardless of length mix
    lengths = sorted({r.prompt_len for r in trace})
    c0 = _jit_cache_size(jit_prefill_chunk(model))
    for i, s in enumerate(lengths):
        warm = [Request(tokens=np.ones((s,), np.int32), max_new_tokens=2,
                        seed=2000 + i)]
        run_continuous_stepwise(session, model, params, warm)
        run_continuous_stepwise(session, model, params, warm,
                                chunk_size=chunk_size,
                                prefill_tokens=prefill_tokens)
    c1 = _jit_cache_size(jit_prefill_chunk(model))
    chunk_exec = None if c0 is None else c1 - c0

    whole = run_continuous_stepwise(session, model, params, trace)
    # the whole-prompt path needs one (1, length) executable per distinct
    # prompt length in the trace — a jit-cache delta would under-count
    # lengths other sections of this benchmark already compiled
    whole["prefill_executables"] = len(lengths)
    chunked = run_continuous_stepwise(session, model, params, trace,
                                      chunk_size=chunk_size,
                                      prefill_tokens=prefill_tokens)
    chunked["prefill_executables"] = chunk_exec
    # the acceptance invariants: bounded per-step prefill, one executable
    assert chunked["max_step_prefill_tokens"] <= budget + chunk_size - 1, \
        "chunked prefill exceeded its per-step token budget"
    assert chunk_exec is None or chunk_exec == 1, \
        "mixed prompt lengths must share ONE compiled chunk executable"
    return {"whole_prompt": whole, "chunked": chunked,
            "chunk_size": chunk_size, "prefill_token_budget": budget}


# ---------------------------------------------------------------------------
# cross-request prefix cache on shared-prefix traffic
# ---------------------------------------------------------------------------


def run_prefix_cache_comparison(model, params, *, requests: int, rate: float,
                                vocab_size: int, max_batch: int, max_seq: int,
                                chunk_size: int, seed: int) -> Dict[str, object]:
    """The same shared-prefix trace with the prefix cache off vs on. One
    warm request per family donates the shared pages first, so the
    measured run isolates steady-state hit behavior; asserts the emitted
    tokens are identical both ways and that prefill tokens drop by at
    least half (the trace shares ~2/3 of every prompt).

    Note the wall-clock comparison is pessimistic at smoke scale: on the
    tiny reduced model a page fetch costs more than recomputing the page,
    so the saved-prefill-tokens count (which scales with model FLOPs) is
    the signal, not smoke tokens/s."""
    page, prefix_len = 4, 16
    trace = poisson_trace(
        requests, rate=rate, vocab_size=vocab_size, prompt_lens=(4, 8),
        new_tokens=(2, 8), prompt_quantum=4, n_prefix_families=2,
        prefix_len=prefix_len, seed=seed)
    fams: Dict[bytes, np.ndarray] = {}
    for r in trace:
        head = np.asarray(r.tokens[:prefix_len])
        fams.setdefault(head.tobytes(), head)
    warm = [Request(tokens=np.concatenate([p, np.full((4,), 1, np.int32)]),
                    max_new_tokens=2, seed=5000 + i)
            for i, p in enumerate(fams.values())]

    results: Dict[str, Dict[str, float]] = {}
    outs: Dict[str, Dict[int, np.ndarray]] = {}
    for label, enable in (("off", False), ("on", True)):
        session = HyperOffloadSession(OffloadConfig(
            mode="continuous", max_batch=max_batch, max_seq=max_seq,
            prefill_budget=2, chunk_size=chunk_size,
            prefix_cache=PrefixCacheConfig(enable=enable, page_size=page)))
        sched = session.scheduler(model, params)
        sched.run(list(warm))              # donate the family prefixes
        base = sched.stats.prefill_tokens
        t0 = time.perf_counter()
        out = sched.run(list(trace))
        wall = time.perf_counter() - t0
        tokens = sum(len(out[r.req_id]) for r in trace)
        results[label] = {
            "prefill_tokens": sched.stats.prefill_tokens - base,
            "tokens": tokens, "wall_s": wall, "tokens_per_s": tokens / wall,
            "prefix_hits": sched.stats.prefix_hits,
            "prefix_hit_tokens": sched.stats.prefix_hit_tokens,
        }
        if enable:
            results[label]["cache"] = session.stats()["prefix"]
        outs[label] = {r.req_id: np.asarray(out[r.req_id]) for r in trace}
        session.close()

    # the acceptance invariants: a hit changes WHAT gets prefilled, never
    # what gets emitted; and shared prefixes stop being re-prefilled
    for r in trace:
        np.testing.assert_array_equal(outs["off"][r.req_id],
                                      outs["on"][r.req_id])
    saved = results["off"]["prefill_tokens"] - results["on"]["prefill_tokens"]
    reduction = saved / max(results["off"]["prefill_tokens"], 1)
    assert reduction >= 0.5, \
        f"prefix cache saved only {reduction:.0%} of prefill tokens"
    return {
        "off": results["off"], "on": results["on"],
        "page_size": page, "prefix_len": prefix_len,
        "prefill_tokens_saved": saved,
        "prefill_reduction": reduction,
        "hit_rate": results["on"]["prefix_hits"] / len(trace),
    }


# ---------------------------------------------------------------------------
# closed-loop calibration: static vs measured planning on a modeled tier
# ---------------------------------------------------------------------------


def run_calibration_comparison(model, params, *, requests: int, rate: float,
                               vocab_size: int, max_batch: int, max_seq: int,
                               seed: int) -> Dict[str, object]:
    """The same kv_offload trace twice over a latency-dominated modeled
    tier: once planned from the static `HardwareSpec`, once after
    ``session.recalibrate()`` folded the first arm's measured per-pair
    transfer telemetry back into planning.

    The topology squeezes the device tier so cold parked pages spill into
    a ``modeled`` tier whose reads cost milliseconds of enforced latency.
    The static arm runs with the engine's default 2 transfer workers —
    per-stream latency serializes a step's fetches and the collect phase
    eats blocked waits. Recalibration measures the per-transfer time and
    the real overlap window, sizes the required in-flight parallelism
    (``core.calibration.required_inflight``) and grows the engine, and
    re-plans on measured bandwidth — so the calibrated arm's fetches run
    concurrently and the same waits come back overlapped. Reported per
    arm: tokens/s, plan lead, hidden_fraction (per-arm trace slice);
    ``scripts/ci.sh`` hard-asserts calibrated >= static on
    hidden_fraction."""
    row = worst_case_page_bytes(model.cache_specs(1, max_seq, jnp.float32))
    topo = TierTopology(tiers=(
        TierSpec("device", kind="device", capacity=1 * row),
        TierSpec("pooled", kind="modeled", read_latency_s=6e-3),
    ))
    session = HyperOffloadSession(OffloadConfig(
        mode="kv_offload", max_batch=max_batch, max_seq=max_seq,
        prefill_budget=2, topology=topo,
        telemetry=TelemetryConfig(enable=True)))
    # pressure matters more than trace length here: enough concurrent
    # rows (arrival rate ≥ 2/step, decodes long enough to overlap) that
    # the one-row device tier spills parked pages every step — the
    # measured in-flight need must genuinely exceed the default 2 workers
    # for the loop to have anything to correct
    n = max(8, requests)
    mk = lambda: poisson_trace(
        n, rate=max(2.0, rate), vocab_size=vocab_size, prompt_lens=(4, 8),
        new_tokens=(6, 12), prompt_quantum=4, seed=seed)
    out: Dict[str, object] = {
        "tier_read_latency_s": topo.spec("pooled").read_latency_s,
        "device_capacity_rows": max(1, max_batch // 4),
    }
    for arm in ("static", "calibrated"):
        if arm == "calibrated":
            spec = session.recalibrate()     # measured replan + worker sizing
            out["hw_calibrated"] = spec.name
            out["measured_r2d_bw"] = spec.pool_bw_r2d
        sched = session.scheduler(model, params)
        n0 = len(session.tracer.events())
        t0 = time.perf_counter()
        res = sched.run(mk())
        wall = time.perf_counter() - t0
        tokens = sum(len(v) for v in res.values())
        ov = OverlapAnalyzer(session.tracer.events()[n0:]).report()
        out[arm] = {
            "tokens": tokens, "wall_s": wall,
            "tokens_per_s": tokens / wall,
            "plan_lead": sched.prefetch_stats()["mean_plan_lead"],
            "transfers": ov["transfers"],
            "hidden_s": ov["hidden_s"], "exposed_s": ov["exposed_s"],
            "hidden_fraction": ov["hidden_fraction"],
            "workers": session.transfer.workers,
        }
        sched.close()
    session.close()
    for arm in ("static", "calibrated"):
        assert out[arm]["hidden_fraction"] is not None, \
            f"calibration {arm} arm traced no transfer time"
    return out


# ---------------------------------------------------------------------------
# SLO-aware scheduling vs FIFO under overload
# ---------------------------------------------------------------------------


def _run_slo_mode(model, params, trace: List[Request], *, max_batch: int,
                  max_seq: int, chunk_size: int,
                  slo: SLOConfig) -> Dict[str, object]:
    """One run of an SLO-annotated trace; FIFO when ``slo`` is disabled.
    Attainment is scored post-hoc from the annotations either way, so the
    two modes are judged by the same yardstick."""
    session = HyperOffloadSession(OffloadConfig(
        mode="continuous", max_batch=max_batch, max_seq=max_seq,
        prefill_budget=2, chunk_size=chunk_size, slo=slo))
    sched = session.scheduler(model, params)
    t0 = time.perf_counter()
    sched.run(list(trace))
    wall = time.perf_counter() - t0
    att = attainment_summary(sched.finished.values())
    steps = max(sched.now, 1e-9)
    res = {
        "tokens": att["tokens"], "wall_s": wall, "virtual_steps": sched.now,
        "goodput_tokens": att["met_tokens"],
        "goodput_tokens_per_step": att["met_tokens"] / steps,
        "tokens_per_step": att["tokens"] / steps,
        "attainment": att,
        "preemptions": sched.stats.preemptions,
        "resumes": sched.stats.resumes,
        "shed": sched.stats.shed,
    }
    session.close()
    return res


def run_overload_comparison(model, params, *, requests: int, vocab_size: int,
                            max_batch: int, max_seq: int, chunk_size: int,
                            seed: int) -> Dict[str, object]:
    """Mixed interactive/batch traffic at 2-5x the scheduler's service
    capacity, FIFO vs SLO-aware admission+preemption over the SAME
    annotated trace. Under overload FIFO's arrival order lets long batch
    work block interactive TTFT deadlines; the SLO policy admits
    deadline-first, preempts batch decodes for deadline-pressed
    interactive arrivals, and sheds infeasible work early — so its
    goodput (deadline-met tokens per virtual step) and interactive TTFT
    attainment must both beat FIFO's (hard-asserted in CI at 3x).

    All metrics are on the deterministic virtual clock — CI-safe."""
    n = max(16, requests)
    # long decodes: slots stay held for tens of steps, so an interactive
    # arrival under overload has to preempt, not just wait for a retire
    prompt_lens, new_toks = (4, 16), (8, 24)
    # service capacity ≈ max_batch slots / mean steps a request holds one
    # (mean prefill chunks + mean decode steps); overload = factor × that
    mean_steps = ((prompt_lens[0] + prompt_lens[1]) / 2) / chunk_size \
        + (new_toks[0] + new_toks[1]) / 2
    capacity_rate = max_batch / mean_steps
    interactive = SLOSpec("interactive", ttft_deadline=10.0)
    batch = SLOSpec("batch")
    out: Dict[str, object] = {
        "requests": n, "capacity_rate": capacity_rate,
        "interactive_fraction": 0.35,
        "ttft_deadline_steps": interactive.ttft_deadline,
    }
    for factor in (2, 3, 5):
        trace = poisson_trace(
            n, rate=factor * capacity_rate, vocab_size=vocab_size,
            prompt_lens=prompt_lens, new_tokens=new_toks, prompt_quantum=4,
            interactive_fraction=0.35, interactive_slo=interactive,
            batch_slo=batch, seed=seed + factor)
        fifo = _run_slo_mode(model, params, trace, max_batch=max_batch,
                             max_seq=max_seq, chunk_size=chunk_size,
                             slo=SLOConfig())
        slo = _run_slo_mode(model, params, trace, max_batch=max_batch,
                            max_seq=max_seq, chunk_size=chunk_size,
                            slo=SLOConfig(enable=True))
        out[f"{factor}x"] = {"fifo": fifo, "slo": slo}
    return out


# ---------------------------------------------------------------------------
# paged decode: fused kernel vs legacy gather, KV codec wire traffic
# ---------------------------------------------------------------------------


def run_decode_kernel(*, steps: int, seed: int) -> Dict[str, object]:
    """The decode hot loop in isolation: one long prefilled context, then
    ``steps`` attention-only decode steps per arm.

    - **gather** — the seed path: every step fetches each pool page and
      ``jnp.concatenate``\\ s before attending.
    - **fused**  — ``attend_fused``: pages install once into the device
      page buffer, the page table indexes them in place (exact-math jnp
      ref; the Pallas kernel's error is reported alongside).

    A second pair of runs restricts the device buffer (``device_pages``)
    so every step's top-k selection pulls pool traffic, measuring on-wire
    bytes per fetch with the codec off vs int8."""
    from repro.offload.kvcache import PagedKVCache
    from repro.pool import default_pool

    b, hq, hkv, d, page, npages, tail = 2, 8, 2, 64, 16, 24, 8
    s0 = npages * page + tail
    ks = jax.random.split(jax.random.key(seed), 2 + steps)
    k_seq = jax.random.normal(ks[0], (b, s0, hkv, d))
    v_seq = jax.random.normal(ks[1], (b, s0, hkv, d))
    qs = [jax.random.normal(ks[2 + t], (b, hq, d)) for t in range(steps)]
    scale = d ** -0.5

    def build(codec=None, device_pages=None):
        pool = default_pool(codec=codec, codec_below="host")
        cache = PagedKVCache.create(batch=b, max_seq=s0 + page,
                                    page_size=page, n_kv_heads=hkv,
                                    head_dim=d, pool=pool,
                                    device_pages=device_pages)
        cache.prefill(k_seq, v_seq)
        return cache

    def timed(cache, attend):
        attend(qs[0])                       # warm the jit outside the clock
        t0 = time.perf_counter()
        outs = [attend(q) for q in qs]
        jax.block_until_ready(outs[-1])
        return outs, time.perf_counter() - t0

    cache = build()
    outs_g, wall_g = timed(
        cache, lambda q: cache.attend(q, scale=scale, top_k_pages=None))
    gather_fetches = cache.fetches
    cache.pool.close()

    cache = build()
    outs_f, wall_f = timed(
        cache, lambda q: cache.attend_fused(q, scale=scale))
    kernel_out = cache.attend_fused(qs[0], scale=scale, use_kernel=True)
    kernel_err = float(jnp.max(jnp.abs(kernel_out - outs_f[0])))
    buffer_hits, buffer_misses = cache.buffer_hits, cache.buffer_misses
    cache.pool.close()

    # token identity: bitwise-equal attention outputs feed bitwise-equal
    # logits, so greedy decoding emits the same tokens
    match = all(bool(jnp.all(f == g)) for f, g in zip(outs_f, outs_g))

    def traffic(codec):
        cache = build(codec=codec, device_pages=4)
        for q in qs:
            cache.attend_fused(q, scale=scale, top_k_pages=4)
        stats = cache.pool_stats()
        per_fetch = stats["bytes_fetched"] / max(cache.fetches, 1)
        cache.pool.close()
        return per_fetch

    bpf_none, bpf_int8 = traffic(None), traffic("int8")

    # quantization error of the full-context int8 page pool vs exact
    cache = build(codec="int8")
    int8_err = float(jnp.max(jnp.abs(
        cache.attend_fused(qs[0], scale=scale) - outs_g[0])))
    cache.pool.close()

    tokens = steps * b
    return {
        "batch": b, "steps": steps, "context": s0, "pages": npages,
        "gather": {"tokens_per_s": tokens / wall_g, "wall_s": wall_g,
                   "pool_fetches": gather_fetches},
        "fused": {"tokens_per_s": tokens / wall_f, "wall_s": wall_f,
                  "buffer_hits": buffer_hits,
                  "buffer_misses": buffer_misses},
        "decode_speedup": wall_g / wall_f,
        "tokens_match_gather": match,
        "kernel_max_abs_err": kernel_err,
        "codec": {"bytes_per_fetch_none": bpf_none,
                  "bytes_per_fetch_int8": bpf_int8,
                  "byte_reduction": bpf_none / bpf_int8,
                  "int8_max_abs_err": int8_err},
    }


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per scheduler step")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--chunk-size", type=int, default=8,
                    help="chunked-prefill chunk for the long-prompt section")
    ap.add_argument("--prefill-tokens", type=int, default=None,
                    help="per-step prefill token budget (default: one chunk)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI; implies --out BENCH_serving.json")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 10)
        args.out = args.out or "BENCH_serving.json"

    cfg = REGISTRY[args.arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    quantum = 4
    lo, hi = 4, min(24, args.max_seq // 2)
    mk = lambda seed: poisson_trace(
        args.requests, rate=args.rate, vocab_size=cfg.vocab_size,
        prompt_lens=(lo, hi), new_tokens=(2, min(16, args.max_seq // 3)),
        prompt_quantum=quantum, seed=seed)

    # one resident session serves the static + continuous baselines
    resident = HyperOffloadSession(OffloadConfig(
        mode="continuous", max_batch=args.max_batch, max_seq=args.max_seq,
        prefill_budget=2))

    # warm every prefill bucket + both decode shapes outside the timed
    # region (jitted entry points are shared across engine/scheduler
    # instances, so these compiles serve the measured runs)
    warm = [Request(tokens=np.ones((s,), np.int32), max_new_tokens=2,
                    seed=1000 + s)
            for s in range(lo, hi + 1, quantum)]
    for r in warm:   # one batch per bucket → every (max_batch, s) prefill
        run_static(resident, model, params, [r], args.max_batch)
    run_continuous(resident, model, params, warm)

    trace = mk(args.seed)
    static = run_static(resident, model, params, trace, args.max_batch)
    cont = run_continuous(resident, model, params, trace)

    # plan-driven prefetch demo: device tier sized to ~half the running
    # batch, so cold sequences' pages spill to host and get fetched back
    # along the planner's refined order
    off_trace = mk(args.seed + 2)[:max(4, args.requests // 2)]
    row = worst_case_page_bytes(model.cache_specs(1, args.max_seq, jnp.float32))
    off_session = HyperOffloadSession(OffloadConfig(
        mode="kv_offload", max_batch=args.max_batch, max_seq=args.max_seq,
        prefill_budget=2,
        device_capacity=max(1, args.max_batch // 2) * row,
        host_capacity=2 * args.max_batch * row,
        telemetry=TelemetryConfig(enable=True)))
    offload = run_continuous(off_session, model, params, off_trace,
                             kv_offload=True)

    # chunked prefill vs whole-prompt on a mixed short/long-prompt trace:
    # long prompts (up to ~3/4 of max_seq) stall every running decode for
    # a whole step under whole-prompt prefill; chunked prefill bounds the
    # per-step prefill work by the token budget and compiles exactly one
    # executable across every prompt length
    new_hi = max(2, min(12, args.max_seq // 4))
    # long prompts up to ~3/4 of max_seq, never inverted for small
    # --max-seq and always leaving room for the decode budget
    long_hi = min(max(args.max_seq // 2, args.max_seq - 16),
                  args.max_seq - new_hi)
    long_lo = min(args.max_seq // 2, long_hi)
    # the quantum grid must intersect both ranges (poisson_trace rejects a
    # range with no on-grid length): shrink the quantum for small max_seq
    # and align the long range's lower bound down onto the grid
    q_long = max(1, min(8, min(hi, long_lo)))
    long_lo = max(q_long, (long_lo // q_long) * q_long)
    long_trace = poisson_trace(
        args.requests, rate=args.rate, vocab_size=cfg.vocab_size,
        prompt_lens=(lo, min(hi, long_lo)), new_tokens=(2, new_hi),
        prompt_quantum=q_long, long_prompt_lens=(long_lo, long_hi),
        long_fraction=0.3, seed=args.seed + 4)
    long_prompts = run_long_prompt_comparison(
        resident, model, params, long_trace, args.chunk_size,
        args.prefill_tokens)

    # cross-request prefix cache on shared-prefix traffic (off vs on)
    prefix_cache = run_prefix_cache_comparison(
        model, params, requests=args.requests, rate=args.rate,
        vocab_size=cfg.vocab_size, max_batch=args.max_batch,
        max_seq=args.max_seq, chunk_size=args.chunk_size,
        seed=args.seed + 6)

    # closed-loop calibration: static vs measured planning over a
    # latency-dominated modeled tier (same trace both arms)
    calibration = run_calibration_comparison(
        model, params, requests=max(4, args.requests // 2), rate=args.rate,
        vocab_size=cfg.vocab_size, max_batch=args.max_batch,
        max_seq=args.max_seq, seed=args.seed + 10)

    # fused paged-decode kernel vs gather/concat + KV codec wire bytes
    decode_kernel = run_decode_kernel(steps=8 if args.smoke else 32,
                                      seed=args.seed + 12)

    # SLO-aware scheduling vs FIFO at 2-5x overload
    overload = run_overload_comparison(
        model, params, requests=args.requests, vocab_size=cfg.vocab_size,
        max_batch=args.max_batch, max_seq=args.max_seq,
        chunk_size=args.chunk_size, seed=args.seed + 8)

    speedup = cont["tokens_per_s"] / static["tokens_per_s"]
    summary = {
        "arch": cfg.name, "requests": args.requests, "rate": args.rate,
        "max_batch": args.max_batch, "max_seq": args.max_seq,
        "static": static, "continuous": cont, "kv_offload": offload,
        "long_prompts": long_prompts, "prefix_cache": prefix_cache,
        "calibration": calibration, "overload": overload,
        "decode_kernel": decode_kernel,
        # the merged front-door snapshot: pool/transfer counters next to
        # the throughput numbers (tracked in BENCH_serving.json)
        "session": off_session.stats(),
        "throughput_speedup": speedup,
        "step_throughput_speedup":
            cont["tokens_per_step"] / static["tokens_per_step"],
    }
    off_session.close()
    resident.close()
    for mode, r in (("static", static), ("continuous", cont),
                    ("kv_offload", offload)):
        print(f"serve_continuous,{mode},tok/s:{r['tokens_per_s']:.1f},"
              f"tok/step:{r['tokens_per_step']:.2f},"
              f"p50:{r['p50_latency_steps']:.1f},"
              f"p99:{r['p99_latency_steps']:.1f}")
    pf, tr = offload["prefetch"], offload["transfer"]
    print(f"serve_continuous,prefetch,plan_lead:{pf['mean_plan_lead']:.1f},"
          f"issued:{pf['fetches_issued']},"
          f"overlapped:{tr['waits_overlapped']},blocked:{tr['waits_blocked']},"
          f"evictions:{offload['pool_evictions']}")
    ov = offload["overlap"]
    hf = ov["hidden_fraction"]
    print(f"serve_continuous,overlap,transfers:{ov['transfers']},"
          f"hidden_s:{ov['hidden_s']:.4f},exposed_s:{ov['exposed_s']:.4f},"
          f"hidden_fraction:"
          f"{'n/a' if hf is None else format(hf, '.2f')}")
    print(f"serve_continuous,speedup,wall:{speedup:.2f},"
          f"steps:{summary['step_throughput_speedup']:.2f}")
    wl, ck = long_prompts["whole_prompt"], long_prompts["chunked"]
    print(f"serve_continuous,long_whole,prefill_stall_max:"
          f"{wl['max_step_prefill_tokens']},p99_step_ms:"
          f"{wl['p99_step_wall_ms']:.1f},executables:"
          f"{wl['prefill_executables']}")
    print(f"serve_continuous,long_chunked,chunk:{args.chunk_size},"
          f"prefill_step_max:{ck['max_step_prefill_tokens']},p99_step_ms:"
          f"{ck['p99_step_wall_ms']:.1f},executables:"
          f"{ck['prefill_executables']}")
    px = prefix_cache
    print(f"serve_continuous,prefix_cache,saved:{px['prefill_tokens_saved']},"
          f"reduction:{px['prefill_reduction']:.0%},"
          f"hit_rate:{px['hit_rate']:.2f},"
          f"tok/s_on:{px['on']['tokens_per_s']:.1f},"
          f"tok/s_off:{px['off']['tokens_per_s']:.1f}")
    for arm in ("static", "calibrated"):
        c = calibration[arm]
        hf = c["hidden_fraction"]
        print(f"serve_continuous,calibration_{arm},"
              f"tok/s:{c['tokens_per_s']:.1f},"
              f"plan_lead:{c['plan_lead']:.1f},"
              f"workers:{c['workers']},"
              f"hidden_fraction:"
              f"{'n/a' if hf is None else format(hf, '.2f')}")
    dk = decode_kernel
    print(f"serve_continuous,decode_kernel,"
          f"gather_tok/s:{dk['gather']['tokens_per_s']:.1f},"
          f"fused_tok/s:{dk['fused']['tokens_per_s']:.1f},"
          f"speedup:{dk['decode_speedup']:.2f},"
          f"match:{dk['tokens_match_gather']},"
          f"kernel_err:{dk['kernel_max_abs_err']:.1e},"
          f"byte_reduction:{dk['codec']['byte_reduction']:.2f}")
    for factor in ("2x", "3x", "5x"):
        fo, so = overload[factor]["fifo"], overload[factor]["slo"]
        f_tta = fo["attainment"]["classes"]["interactive"]["ttft_attainment"]
        s_tta = so["attainment"]["classes"]["interactive"]["ttft_attainment"]
        print(f"serve_continuous,overload_{factor},"
              f"goodput_fifo:{fo['goodput_tokens_per_step']:.2f},"
              f"goodput_slo:{so['goodput_tokens_per_step']:.2f},"
              f"ttft_att_fifo:{f_tta:.2f},ttft_att_slo:{s_tta:.2f},"
              f"preemptions:{so['preemptions']},shed:{so['shed']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"serve_continuous,written,{args.out}")


if __name__ == "__main__":
    main()
