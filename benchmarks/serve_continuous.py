"""Continuous batching vs. static batching on mixed-length Poisson traffic.

Two schedulers over the same arrival trace and the same model:

- **static**  — the seed's serving pattern: requests are grouped into
  arrival-order batches of ``max_batch``; a batch prefills together
  (prompts end-padded to the batch max) and decodes until its *longest*
  member finishes — short requests burn padded decode steps and late
  requests wait for the whole previous batch.
- **continuous** — ``sched.ContinuousScheduler``: sequences join and
  retire every decode step, so a retired slot is refilled immediately and
  nobody decodes padding.

Reported: wall-clock generated tokens/s, virtual-step throughput, and
p50/p99 request latency in scheduler steps (finish − arrival on the
deterministic virtual clock; 1 step = one batched decode). A second,
small ``kv_offload`` run reports the plan-driven prefetcher's stats —
fetches issued ahead of consumption (plan lead ≥ 1, overlapped waits)
instead of the old store-then-immediately-wait round trip.

    PYTHONPATH=src python benchmarks/serve_continuous.py [--smoke] [--out F]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import HyperOffloadSession, OffloadConfig
from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.offload.kvcache import worst_case_page_bytes
from repro.sched import Request, poisson_trace


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


# ---------------------------------------------------------------------------
# static-batching baseline
# ---------------------------------------------------------------------------


def run_static(session, model, params, trace: List[Request],
               max_batch: int) -> Dict[str, float]:
    engine = session.serve_engine(model, params, offload_kv=False)
    clock = 0.0
    latencies: List[float] = []
    tokens = 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), max_batch):
        batch = trace[i:i + max_batch]
        start = max(clock, max(r.arrival for r in batch))
        s_max = max(r.prompt_len for r in batch)
        # a partial final batch is padded with copies of its last request
        # (uncounted) so the engine only ever sees full-batch shapes
        padded = np.zeros((max_batch, s_max), np.int32)
        for j in range(max_batch):
            r = batch[min(j, len(batch) - 1)]
            padded[j, :r.prompt_len] = r.tokens
        steps = max(r.max_new_tokens for r in batch)
        engine.generate({"tokens": jnp.asarray(padded)}, steps)
        clock = start + steps        # everyone waits for the longest member
        tokens += sum(r.max_new_tokens for r in batch)
        latencies += [clock - r.arrival for r in batch]
    wall = time.perf_counter() - t0
    engine.close()
    return {
        "tokens": tokens, "wall_s": wall, "virtual_steps": clock,
        "tokens_per_s": tokens / wall,
        "tokens_per_step": tokens / max(clock, 1e-9),
        "p50_latency_steps": _pct(latencies, 50),
        "p99_latency_steps": _pct(latencies, 99),
    }


# ---------------------------------------------------------------------------
# continuous scheduler
# ---------------------------------------------------------------------------


def run_continuous(session, model, params, trace: List[Request], *,
                   kv_offload: bool = False) -> Dict[str, float]:
    sched = session.scheduler(model, params, kv_offload=kv_offload)
    t0 = time.perf_counter()
    out = sched.run(trace)
    wall = time.perf_counter() - t0
    tokens = sum(len(v) for v in out.values())
    lats = [st.t_done - st.request.arrival for st in sched.finished.values()]
    res = {
        "tokens": tokens, "wall_s": wall, "virtual_steps": sched.now,
        "tokens_per_s": tokens / wall,
        "tokens_per_step": tokens / max(sched.now, 1e-9),
        "p50_latency_steps": _pct(lats, 50),
        "p99_latency_steps": _pct(lats, 99),
        "joins": sched.stats.joins, "retires": sched.stats.retires,
        "admission_blocked": sched.admission.blocked,
    }
    if kv_offload:
        snap = sched.pool_stats()
        res["prefetch"] = sched.prefetch_stats()
        res["transfer"] = snap["transfer"]
        res["pool_evictions"] = snap["evictions"]
        res["pages_parked"] = sched.stats.pages_parked
        res["cold_spills"] = sched.stats.cold_spills
    sched.close()
    return res


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per scheduler step")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for CI; implies --out BENCH_serving.json")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 10)
        args.out = args.out or "BENCH_serving.json"

    cfg = REGISTRY[args.arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    quantum = 4
    lo, hi = 4, min(24, args.max_seq // 2)
    mk = lambda seed: poisson_trace(
        args.requests, rate=args.rate, vocab_size=cfg.vocab_size,
        prompt_lens=(lo, hi), new_tokens=(2, min(16, args.max_seq // 3)),
        prompt_quantum=quantum, seed=seed)

    # one resident session serves the static + continuous baselines
    resident = HyperOffloadSession(OffloadConfig(
        mode="continuous", max_batch=args.max_batch, max_seq=args.max_seq,
        prefill_budget=2))

    # warm every prefill bucket + both decode shapes outside the timed
    # region (jitted entry points are shared across engine/scheduler
    # instances, so these compiles serve the measured runs)
    warm = [Request(tokens=np.ones((s,), np.int32), max_new_tokens=2,
                    seed=1000 + s)
            for s in range(lo, hi + 1, quantum)]
    for r in warm:   # one batch per bucket → every (max_batch, s) prefill
        run_static(resident, model, params, [r], args.max_batch)
    run_continuous(resident, model, params, warm)

    trace = mk(args.seed)
    static = run_static(resident, model, params, trace, args.max_batch)
    cont = run_continuous(resident, model, params, trace)

    # plan-driven prefetch demo: device tier sized to ~half the running
    # batch, so cold sequences' pages spill to host and get fetched back
    # along the planner's refined order
    off_trace = mk(args.seed + 2)[:max(4, args.requests // 2)]
    row = worst_case_page_bytes(model.cache_specs(1, args.max_seq, jnp.float32))
    off_session = HyperOffloadSession(OffloadConfig(
        mode="kv_offload", max_batch=args.max_batch, max_seq=args.max_seq,
        prefill_budget=2,
        device_capacity=max(1, args.max_batch // 2) * row,
        host_capacity=2 * args.max_batch * row))
    offload = run_continuous(off_session, model, params, off_trace,
                             kv_offload=True)

    speedup = cont["tokens_per_s"] / static["tokens_per_s"]
    summary = {
        "arch": cfg.name, "requests": args.requests, "rate": args.rate,
        "max_batch": args.max_batch, "max_seq": args.max_seq,
        "static": static, "continuous": cont, "kv_offload": offload,
        # the merged front-door snapshot: pool/transfer counters next to
        # the throughput numbers (tracked in BENCH_serving.json)
        "session": off_session.stats(),
        "throughput_speedup": speedup,
        "step_throughput_speedup":
            cont["tokens_per_step"] / static["tokens_per_step"],
    }
    off_session.close()
    resident.close()
    for mode, r in (("static", static), ("continuous", cont),
                    ("kv_offload", offload)):
        print(f"serve_continuous,{mode},tok/s:{r['tokens_per_s']:.1f},"
              f"tok/step:{r['tokens_per_step']:.2f},"
              f"p50:{r['p50_latency_steps']:.1f},"
              f"p99:{r['p99_latency_steps']:.1f}")
    pf, tr = offload["prefetch"], offload["transfer"]
    print(f"serve_continuous,prefetch,plan_lead:{pf['mean_plan_lead']:.1f},"
          f"issued:{pf['fetches_issued']},"
          f"overlapped:{tr['waits_overlapped']},blocked:{tr['waits_blocked']},"
          f"evictions:{offload['pool_evictions']}")
    print(f"serve_continuous,speedup,wall:{speedup:.2f},"
          f"steps:{summary['step_throughput_speedup']:.2f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
        print(f"serve_continuous,written,{args.out}")


if __name__ == "__main__":
    main()
