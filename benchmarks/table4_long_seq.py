"""Table 4 reproduction: long-sequence inference stability.

Paper: with device memory near capacity, the resident-KV baseline triggers
57 defragmentation events (prefill 129.3 s); hierarchical memory
eliminates them (99.4 s prefill, −23.1 %; end-to-end −13.8 %).

Fragmentation model: long-context serving keeps *multiple concurrent KV
lifecycles* (§2.1's RAG sub-queries / multi-turn sessions). Requests of
varying lengths arrive and retire; each grows its KV cache in chunks
interleaved with transient activation buffers. Near capacity, first-fit
leaves holes no new chunk fits, forcing compactions. The offloaded variant
streams KV chunks to the pool as they are produced, so the device working
set stays small and the allocator never fragments.

Each compaction costs a pipeline-drain stall (DEFRAG_STALL, calibrated to
the paper's ~0.52 s/event) + live-byte movement at HBM bandwidth; the
defrag COUNT and its elimination are the model's predictions.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.allocator import FirstFitAllocator
from repro.core import insertion, timeline, tracer
from repro.core.costmodel import ASCEND_LIKE

from benchmarks.paper_models import DEEPSEEK_V3_FULL
from benchmarks.table5_short_seq import decode_token_time

SHARDS = 8
BATCH = 26
SEQ = 71_000
W4 = 0.53
KV_READ_FRACTION = 0.06
DEFRAG_STALL = 0.45
DECODE_TOKENS = 512
CAPACITY = 64e9

KV_PER_TOKEN = DEEPSEEK_V3_FULL.kv_bytes_per_token(2) * BATCH / SHARDS
WEIGHTS = DEEPSEEK_V3_FULL.param_count() * W4 / SHARDS
CHUNK_TOKENS = 2048


def serving_trace(seed: int = 0, n_requests: int = 96,
                  remote_kv: bool = False) -> Tuple[int, int]:
    """Replay a staggered multi-request serving episode through the
    allocator; returns (defrag_events, bytes_moved)."""
    rng = np.random.default_rng(seed)
    alloc = FirstFitAllocator(int(CAPACITY - WEIGHTS), alignment=4096)
    live: List[Tuple[str, int]] = []   # (request prefix, n_chunks)
    defrag0 = 0
    uid = 0
    for r in range(n_requests):
        seq = int(rng.uniform(0.3, 1.0) * SEQ)
        n_chunks = max(1, seq // CHUNK_TOKENS)
        chunk_bytes = int(KV_PER_TOKEN * CHUNK_TOKENS)
        if remote_kv:
            chunk_bytes = max(4096, int(chunk_bytes * KV_READ_FRACTION))
        # retire one or two old requests to make room (staggered lifecycle)
        while live and (len(live) >= 4 or rng.uniform() < 0.3):
            name, k = live.pop(0)
            for c in range(k):
                alloc.free(f"{name}_c{c}")
        name = f"r{uid}"
        uid += 1
        ok = True
        for c in range(n_chunks):
            # transient activation buffer churn between chunk allocations
            tb = f"{name}_t{c}"
            alloc.alloc(tb, int(rng.uniform(0.5, 2.0) * 256e6))
            if not alloc.alloc(f"{name}_c{c}", chunk_bytes):
                ok = False
            alloc.free(tb)
            if not ok:
                break
        live.append((name, n_chunks))
    return alloc.stats.defrag_events, alloc.stats.bytes_moved


def _prefill_compute(remote_kv: bool) -> float:
    opts = tracer.TraceOptions(shards=SHARDS, remote_kv=remote_kv,
                               remote_opt_states=False, weight_dtype_bytes=W4,
                               kv_read_fraction=KV_READ_FRACTION)
    g = tracer.trace_prefill(DEEPSEEK_V3_FULL, BATCH, SEQ, opts)
    if remote_kv:
        g = insertion.insert_cache_ops(
            g, ASCEND_LIKE,
            insertion.InsertionOptions(offload_activations=False,
                                       force_prefixes=("kv_",)))
    else:
        g = g.residentize()
    return timeline.simulate(g, ASCEND_LIKE).total


def run() -> List[Dict]:
    ev_base, moved_base = serving_trace(remote_kv=False)
    ev_off, moved_off = serving_trace(remote_kv=True)

    pre_base = (_prefill_compute(False) + ev_base * DEFRAG_STALL
                + moved_base / ASCEND_LIKE.hbm_bw)
    pre_off = (_prefill_compute(True) + ev_off * DEFRAG_STALL
               + moved_off / ASCEND_LIKE.hbm_bw)
    dec_base = decode_token_time(False, seq=SEQ)
    dec_off = decode_token_time(True, seq=SEQ)
    e2e_base = pre_base + DECODE_TOKENS * dec_base
    e2e_off = pre_off + DECODE_TOKENS * dec_off

    return [{
        "metric": "defrag_events",
        "baseline": ev_base, "hierarchical": ev_off,
        "paper_baseline": 57, "paper_hier": 0,
    }, {
        "metric": "prefill_latency_s",
        "baseline": pre_base, "hierarchical": pre_off,
        "relative_change": (pre_off - pre_base) / pre_base,
        "paper_change": -0.2313,
    }, {
        "metric": "end_to_end_latency_s",
        "baseline": e2e_base, "hierarchical": e2e_off,
        "relative_change": (e2e_off - e2e_base) / e2e_base,
        "paper_change": -0.1378,
    }]


def main():
    for r in run():
        print("table4,%s,%.2f,%.2f,%s" % (
            r["metric"], r["baseline"], r["hierarchical"],
            ("%.3f vs paper %.3f" % (r["relative_change"], r["paper_change"]))
            if "relative_change" in r else
            "paper: %s->%s" % (r["paper_baseline"], r["paper_hier"])))


if __name__ == "__main__":
    main()
