"""Table 6 + §7.4 reproduction: sensitivity to sparse-block granularity.

Paper Table 6: peak memory −21.6 %, prefill −4.1 %, decode +25.5 %, total
≈0.15 % at their block setting; §7.4 observes decode degradation grows
with block size. We sweep block efficiency (coarser blocks ⇒ more CPU
copy/processing per useful byte) and report the paper's operating point
(efficiency 1.0) plus the sensitivity curve.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import insertion, memsim, tracer
from repro.core.costmodel import ASCEND_LIKE

from benchmarks.paper_models import DEEPSEEK_V3_FULL
from benchmarks.table5_short_seq import (
    BATCH, DECODE_TOKENS, KV_READ_FRACTION, SEQ_SHORT, SHARDS, W4,
    decode_token_time, prefill_time,
)

BLOCK_EFFICIENCIES = [1.0, 0.5, 0.25, 0.125]   # 1.0 = paper's block size


def peak_memory(remote_kv: bool) -> float:
    opts = tracer.TraceOptions(shards=SHARDS, remote_kv=remote_kv,
                               remote_opt_states=False, weight_dtype_bytes=W4,
                               kv_read_fraction=KV_READ_FRACTION)
    g = tracer.trace_decode_step(DEEPSEEK_V3_FULL, BATCH, SEQ_SHORT * 4, opts)
    if remote_kv:
        g = insertion.insert_cache_ops(
            g, ASCEND_LIKE,
            insertion.InsertionOptions(offload_activations=False,
                                       force_prefixes=("kv_",)))
        return memsim.simulate(g).peak_bytes
    return memsim.simulate(g.residentize()).peak_bytes


def run() -> List[Dict]:
    rows = []
    mb, mo = peak_memory(False), peak_memory(True)
    rows.append({
        "metric": "peak_memory_mb", "block_eff": 1.0,
        "baseline": mb / 1e6, "hierarchical": mo / 1e6,
        "relative_change": (mo - mb) / mb, "paper_change": -0.2157,
    })
    dec_b = decode_token_time(False)
    pre_b, pre_o = prefill_time(False), prefill_time(True)
    for eff in BLOCK_EFFICIENCIES:
        dec_o = decode_token_time(True, block_efficiency=eff)
        rows.append({
            "metric": "decode_predict_time_s", "block_eff": eff,
            "baseline": dec_b, "hierarchical": dec_o,
            "relative_change": (dec_o - dec_b) / dec_b,
            "paper_change": 0.2547 if eff == 1.0 else None,
        })
        rows.append({
            "metric": "total_time_s", "block_eff": eff,
            "baseline": pre_b + DECODE_TOKENS * dec_b,
            "hierarchical": pre_o + DECODE_TOKENS * dec_o,
            "relative_change": ((pre_o + DECODE_TOKENS * dec_o)
                                - (pre_b + DECODE_TOKENS * dec_b))
                               / (pre_b + DECODE_TOKENS * dec_b),
            "paper_change": 0.0015 if eff == 1.0 else None,
        })
    return rows


def main():
    for r in run():
        paper = ("paper:%.4f" % r["paper_change"]) if r.get("paper_change") is not None else "paper:-"
        print("table6,%s,eff=%.3f,%.3f,%.3f,%.4f,%s" % (
            r["metric"], r["block_eff"], r["baseline"], r["hierarchical"],
            r["relative_change"], paper))


if __name__ == "__main__":
    main()
