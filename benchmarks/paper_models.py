"""Model configs for the paper's own evaluation workloads (§7).

These are *benchmark-only* configs (the 10 assigned architectures live in
src/repro/configs): LLaMA-8B [arXiv:2407.21783] and a DeepSeek-V3-like
MoE+MLA config [arXiv:2412.19437] used for the Fig. 6 / Tables 3-6
reproductions.
"""

from repro.configs.base import (
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    Segment,
)

DENSE = LayerSpec(mixer="attn", ffn="swiglu")
MOE_MLA = LayerSpec(mixer="mla", ffn="moe")

LLAMA8B = ModelConfig(
    name="llama3-8b",
    family="dense",
    citation="arXiv:2407.21783",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    segments=(Segment(pattern=(DENSE,), repeats=32),),
    rope_theta=500000.0,
    tie_embeddings=False,
)

# DeepSeek-V3: 61 layers, d_model 7168, MLA, 256 routed experts top-8
# (d_ff_expert 2048). The full 671B model's states (~8 TB) cannot exist on
# the paper's stated 8-NPU node under any parallelism, so — like the paper's
# own experiment must have — we use a node-scale proxy: same depth/width/
# MLA dims, 10 routed experts (≈40B params, ≈24B active), which saturates
# the 8×64 GB node exactly the way §7.2.2 describes. Documented deviation.
# Full-size DeepSeek-V3 (256 experts) — used only for analytic memory math
# in the inference tables (no arrays are ever materialized from this).
DEEPSEEK_V3_FULL = ModelConfig(
    name="deepseek-v3-full",
    family="moe",
    citation="arXiv:2412.19437",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=64,
    d_ff=18432,
    vocab_size=129280,
    segments=(Segment(pattern=(MOE_MLA,), repeats=61),),
    tie_embeddings=False,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25),
)

DEEPSEEK_V3 = ModelConfig(
    name="deepseek-v3-like",
    family="moe",
    citation="arXiv:2412.19437",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=64,
    d_ff=18432,
    vocab_size=129280,
    segments=(Segment(pattern=(MOE_MLA,), repeats=61),),
    tie_embeddings=False,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=10, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25),
)
