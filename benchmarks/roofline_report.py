"""Regenerate the EXPERIMENTS.md §Roofline tables from dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.roofline_report \
        [--records results/dryrun.json] [--mesh 16x16|2x16x16|all]
"""

from __future__ import annotations

import argparse
import json

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def table(records, mesh: str) -> str:
    rows = [r for r in records if "roofline" in r and r.get("mesh") == mesh]
    out = ["| arch | shape | peak GB/dev | compute s | memory s | "
           "collective s | dominant | useful-FLOPs |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], SHAPE_ORDER[r["shape"]])):
        t = r["roofline"]
        out.append("| %s | %s | %.2f | %.3f | %.3f | %.3f | **%s** | %.2f |" % (
            r["arch"], r["shape"], r["memory_analysis"]["peak_gb"],
            t["compute_s"], t["memory_s"], t["collective_s"], t["dominant"],
            t["useful_flops_ratio"]))
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default="results/dryrun.json")
    ap.add_argument("--mesh", default="all")
    args = ap.parse_args(argv)
    records = json.load(open(args.records))
    meshes = ("16x16", "2x16x16") if args.mesh == "all" else (args.mesh,)
    for m in meshes:
        print(f"\n## mesh {m}\n")
        print(table(records, m))
    fails = [r for r in records if "error" in r]
    if fails:
        print(f"\n{len(fails)} FAILED combos:")
        for r in fails:
            print(" ", r["arch"], r["shape"], r["mesh"], r["error"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
