"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig6,table3,...]

Prints ``name,<fields...>`` CSV rows per benchmark plus timing per module
(the quantities EXPERIMENTS.md tracks).
"""

from __future__ import annotations

import argparse
import sys
import time


BENCHES = ("fig6", "table3", "table4", "table5", "table6")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else set(BENCHES)

    import benchmarks.fig6_training_bandwidth as fig6
    import benchmarks.table3_kv_offload as t3
    import benchmarks.table4_long_seq as t4
    import benchmarks.table5_short_seq as t5
    import benchmarks.table6_sparse_blocks as t6

    mods = {"fig6": fig6, "table3": t3, "table4": t4, "table5": t5,
            "table6": t6}
    print("benchmark,fields...")
    for name in BENCHES:
        if name not in only:
            continue
        t0 = time.time()
        mods[name].main()
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
