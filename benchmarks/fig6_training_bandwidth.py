"""Figure 6 reproduction: end-to-end training step time vs D2H bandwidth.

Paper setup (§7.2): LLaMA-8B and DeepSeek-V3 trained on an 8-NPU node.
The *baseline* satisfies memory via full activation recomputation (their
Table 1/2 configs); *hierarchical memory* instead offloads activations to
the pool, choosing per-bandwidth how many layers' activations to offload
(the rest still recompute) so the DMA traffic stays hidden.

Paper claims: ≈parity at the measured 33.6 GB/s; +5.7–21.5 % (LLaMA-8B)
and +2–12.3 % (DeepSeek-V3) over 40–70 GB/s.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import insertion, memsim, timeline, tracer
from repro.core.costmodel import ASCEND_LIKE

from benchmarks.paper_models import DEEPSEEK_V3, LLAMA8B

BANDWIDTHS = [33.6e9, 40e9, 50e9, 60e9, 70e9]
SHARDS = 8          # 8-NPU node, DP=8
CAPACITY = 64e9     # HBM per NPU


def _step_time(cfg, batch, seq, hw, n_offload: int, opt_states_remote: bool):
    """Simulated step time when the first ``n_offload`` layers' activations
    are pool-offloaded and the rest recompute."""
    n_layers = cfg.n_layers
    recompute = frozenset(range(n_offload, n_layers))
    opts = tracer.TraceOptions(shards=SHARDS,
                               remote_opt_states=opt_states_remote)
    g = tracer.trace_train_step(cfg, batch, seq, opts, recompute_layers=recompute)
    force = tuple(f"act_{i}" for i in range(n_offload))
    g2 = insertion.insert_cache_ops(
        g, hw, insertion.InsertionOptions(
            offload_activations=False, offload_states=opt_states_remote,
            force_tensors=force))
    tl = timeline.simulate(g2, hw)
    mem = memsim.simulate(g2)
    return tl, mem


def run(batch: int = 16, seq: int = 4096) -> List[Dict]:
    rows = []
    for cfg in (LLAMA8B, DEEPSEEK_V3):
        base_hw = ASCEND_LIKE
        base_tl, base_mem = _step_time(cfg, batch, seq, base_hw, 0, False)
        for bw in BANDWIDTHS:
            hw = ASCEND_LIKE.with_pool_bw(bw)
            best = None
            for k in range(0, cfg.n_layers + 1, max(1, cfg.n_layers // 8)):
                # hierarchical memory offloads activations of k layers AND
                # parks optimizer states in the pool (the paper's
                # "activations and a subset of parameters", §7.2.1)
                tl, mem = _step_time(cfg, batch, seq, hw, k, True)
                if mem.peak_bytes > CAPACITY:
                    continue
                if best is None or tl.total < best[0].total:
                    best = (tl, mem, k)
            if best is None:
                continue  # nothing fits this capacity
            tl, mem, k = best
            rows.append({
                "model": cfg.name,
                "bw_gbs": bw / 1e9,
                "baseline_ms": base_tl.total * 1e3,
                "hyper_ms": tl.total * 1e3,
                "improvement_pct": 100 * (base_tl.total - tl.total) / base_tl.total,
                "exposed_ms": tl.exposed_comm * 1e3,
                "offloaded_layers": k,
                "base_peak_gb": base_mem.peak_bytes / 1e9,
                "hyper_peak_gb": mem.peak_bytes / 1e9,
            })
    return rows


def main():
    for r in run():
        print("fig6,%s,%.1f,%.1f,%.1f,%.2f,%d" % (
            r["model"], r["bw_gbs"], r["baseline_ms"], r["hyper_ms"],
            r["improvement_pct"], r["offloaded_layers"]))


if __name__ == "__main__":
    main()
