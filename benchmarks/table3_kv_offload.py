"""Table 3 reproduction: KV-cache offload — peak device memory and maximum
supported sequence length.

Paper setting: DeepSeek-V3 + NSA inference on an 8-NPU node (61.2→45.0 GB
peak, −26 %; max sequence 71k → 123k, ≈1.73×).

Modeling notes (documented deviations):
- full DeepSeek-V3 weights (671B) cannot be bf16 on a 64 GB×8 node; the
  composition only closes with ~4-bit quantized serving weights
  (671B × 0.53 B / 8 ≈ 45 GB/NPU) — exactly the paper's post-offload peak,
  confirming weights dominate their residual 45 GB. We model W4.
- MLA compresses KV to (512+64) B/token/layer; batch 26 at 71k tokens gives
  the ~16 GB/NPU KV slice the paper's Δ implies.
- with KV pooled, max sequence is bound by the node's pool share
  (POOL_SHARE, a stated assumption: 256 GB of CloudMatrix pooled DRAM per
  8-NPU node).
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import insertion, memsim, tracer
from repro.core.costmodel import ASCEND_LIKE

from benchmarks.paper_models import DEEPSEEK_V3_FULL

SHARDS = 8
CAPACITY = 64e9
POOL_SHARE = 256e9
BATCH = 26
KV_READ_FRACTION = 0.06   # NSA sparse block selection
W4 = 0.53                 # ~4.2 bits/weight incl. scales


def _opts(remote_kv: bool) -> tracer.TraceOptions:
    return tracer.TraceOptions(shards=SHARDS, remote_kv=remote_kv,
                               kv_read_fraction=KV_READ_FRACTION,
                               remote_opt_states=False,
                               weight_dtype_bytes=W4)


def peak_at(cfg, seq: int, remote_kv: bool) -> float:
    g = tracer.trace_decode_step(cfg, BATCH, seq, _opts(remote_kv))
    if remote_kv:
        g = insertion.insert_cache_ops(
            g, ASCEND_LIKE,
            insertion.InsertionOptions(offload_activations=False,
                                       force_prefixes=("kv_",)))
        return memsim.simulate(g).peak_bytes
    return memsim.simulate(g.residentize()).peak_bytes


def kv_bytes_per_token_global(cfg) -> float:
    return cfg.kv_bytes_per_token(2) * BATCH


def max_seq(cfg, remote_kv: bool, hi: int = 1 << 21) -> int:
    lo, best = 1024, 0
    while lo <= hi:
        mid = (lo + hi) // 2
        if peak_at(cfg, mid, remote_kv) <= CAPACITY:
            best, lo = mid, mid + 1024
        else:
            hi = mid - 1024
    if remote_kv:
        pool_bound = int(POOL_SHARE / kv_bytes_per_token_global(cfg) * SHARDS / SHARDS)
        best = min(best, pool_bound)
    return best


def run() -> List[Dict]:
    cfg = DEEPSEEK_V3_FULL
    seq_ref = 71_000
    base_peak = peak_at(cfg, seq_ref, False)
    off_peak = peak_at(cfg, seq_ref, True)
    base_max = max_seq(cfg, False)
    off_max = max_seq(cfg, True)
    return [{
        "metric": "peak_device_memory_gb",
        "baseline": base_peak / 1e9,
        "hierarchical": off_peak / 1e9,
        "relative_change": (off_peak - base_peak) / base_peak,
        "paper_baseline": 61.2, "paper_hier": 45.0, "paper_change": -0.26,
    }, {
        "metric": "max_sequence_length_tokens",
        "baseline": base_max,
        "hierarchical": off_max,
        "relative_change": off_max / max(base_max, 1),
        "paper_baseline": 71_000, "paper_hier": 123_000, "paper_change": 1.73,
    }]


def runtime_pool_stats() -> Dict:
    """Drive a small PagedKVCache through the real pool manager and report
    the measured transfer traffic — the runtime counterpart of the analytic
    rows above (absolute sizes are toy; the ratios are the point)."""
    import jax

    from repro.api import HyperOffloadSession, OffloadConfig

    b, hkv, d, page, ctx = 2, 4, 64, 32, 512
    with HyperOffloadSession(OffloadConfig(mode="paged", max_seq=ctx + page,
                                           page_size=page)) as session:
        cache = session.paged_kv(batch=b, n_kv_heads=hkv, head_dim=d)
        ks = jax.random.split(jax.random.key(0), 3)
        cache.prefill(jax.random.normal(ks[0], (b, ctx, hkv, d)),
                      jax.random.normal(ks[1], (b, ctx, hkv, d)))
        q = jax.random.normal(ks[2], (b, 8, d))
        for top_k in (None, 4, 2):          # dense + two sparse settings
            cache.attend(q, scale=d ** -0.5, top_k_pages=top_k)
        return cache.pool_stats()


def main():
    for r in run():
        print("table3,%s,%.1f,%.1f,%.3f,paper:%.3f" % (
            r["metric"], r["baseline"], r["hierarchical"],
            r["relative_change"], r["paper_change"]))
    s = runtime_pool_stats()
    host = s["tier/host"]
    print("table3,pool_stats,puts:%d,gets:%d,stored_mb:%.2f,fetched_mb:%.2f,"
          "host_peak_mb:%.2f,backend:%s" % (
              s["puts"], s["gets"], s["bytes_stored"] / 1e6,
              s["bytes_fetched"] / 1e6, host["peak"] / 1e6, host["backend"]))


if __name__ == "__main__":
    main()
